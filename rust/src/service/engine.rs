//! The sharded scoring engine: N shard workers, each owning a
//! `SessionRegistry` and fed by a bounded channel. `submit` hashes the
//! session id to a shard and blocks when that shard's queue is full
//! (backpressure); `finish` drains the workers and aggregates per-session
//! reports. See the module docs in `service/mod.rs` for the full model.

use super::config::ServiceConfig;
use super::registry::{shard_of, SessionRegistry};
use super::session::{encode_session_id, SessionReport, SessionSnapshot, SessionState};
use crate::durability::wal::{WalReader, WalRecord, WalWriter};
use crate::durability::{recovery, snapshot, EpochCut, OnError};
use crate::entropy::FingerState;
use crate::graph::Graph;
use crate::stream::{checkpoint, StreamEvent};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Durability health, shared between the shard workers (who detect WAL
/// failures) and the network front end (who surfaces / gates on them).
pub const DUR_OK: u8 = 0;
/// A WAL failure was absorbed under `on_error = degrade`: the affected
/// shard(s) dropped their WAL and keep scoring without durability.
pub const DUR_DEGRADED: u8 = 1;
/// A WAL failure under `on_error = fail_stop`: mutating commands are
/// refused until an epoch cut restores a healthy log.
pub const DUR_FAILED: u8 = 2;

/// Exactly-once bookkeeping for one reliable session (`OPEN ... epoch=`).
/// Sequence state is in-memory only — a server restart clears it, which the
/// client observes as a fresh epoch and resyncs from (`docs/ROBUSTNESS.md`).
struct ReliableEntry {
    /// Server-assigned session epoch; a reliable `OPEN` carrying it resumes
    /// instead of resetting.
    epoch: u64,
    /// Highest applied sequence number.
    acked: u64,
}

/// Cap on tracked reliable sessions: an `OPEN`-churning client must not grow
/// server memory without bound. Past the cap the insert evicts an arbitrary
/// entry — that session falls back to fresh-epoch semantics on its next
/// reliable `OPEN` (safe: reset, never duplicated application).
const RELIABLE_CAP: usize = 65_536;

/// Verdict on one reliable write's sequence number.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqOutcome {
    /// `seq == acked + 1`: apply it (and ack on success).
    Apply,
    /// `seq <= acked`: already applied — discard, report `dup`.
    Duplicate { acked: u64 },
    /// `seq > acked + 1` (or no reliable session): refuse, report the gap.
    Gap { acked: u64 },
}

/// Message routed to a shard worker. Per-session ordering is guaranteed by
/// the single FIFO channel each shard consumes.
enum ShardMsg {
    /// (Re)open a session with an explicit state.
    Open { id: String, state: FingerState },
    /// One stream event for a session.
    Event { id: String, ev: StreamEvent },
    /// A batch of events for one session (amortizes the per-message routing
    /// and channel cost on the ingest path).
    Batch { id: String, events: Vec<StreamEvent> },
    /// Point-in-time read of a session's live stats. Flows through the same
    /// FIFO channel as events, so a query observes everything the caller
    /// submitted before it.
    Query { id: String, reply: Sender<Option<SessionSnapshot>> },
    /// Retire a session: flush its trailing partial window, free the shard
    /// state and reply with the final snapshot (`None` if unknown). FIFO
    /// ordering means the close observes every event submitted before it.
    Close { id: String, reply: Sender<Option<SessionSnapshot>> },
    /// Epoch barrier (broadcast to every shard, never routed by id): rotate
    /// the WAL, canonicalize live states, checkpoint them into `dir`, and
    /// reply with the shard's cut. FIFO ordering makes the cut consistent
    /// with everything submitted before the barrier.
    Epoch { dir: PathBuf, epoch: u64, reply: Sender<anyhow::Result<EpochCut>> },
}

/// Submission failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The target shard's worker is gone (it panicked — workers otherwise
    /// outlive every sender).
    Closed { shard: usize },
    /// Non-blocking submission (`try_submit*`) found the shard's bounded
    /// queue full; the blocking `submit` path waits instead of failing.
    WouldBlock { shard: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Closed { shard } => {
                write!(f, "shard {shard} is no longer accepting events")
            }
            SubmitError::WouldBlock { shard } => {
                write!(f, "shard {shard}'s queue is full (would block)")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// The running service. `submit` may be called from any number of threads
/// (`&self`, channels are `Sync`); `finish` consumes the service, joins the
/// workers and returns the aggregate report.
pub struct ScoringService {
    cfg: ServiceConfig,
    senders: Vec<SyncSender<ShardMsg>>,
    workers: Vec<JoinHandle<ShardOutcome>>,
    /// Messages in flight per shard (queued + the one being processed);
    /// incremented on send, decremented by the worker as it picks each up.
    depths: Vec<Arc<AtomicUsize>>,
    submitted: AtomicUsize,
    start: Instant,
    /// Next epoch number to cut (continues past the recovered epoch); the
    /// lock also serializes whole epoch commits, barrier through publish.
    epoch: Mutex<u64>,
    /// What startup recovery rebuilt (all zeroes for a fresh start).
    recovery: RecoveryReport,
    /// Exactly-once state per reliable session (epoch + highest applied
    /// seq). In-memory only: cleared by restart, capped at [`RELIABLE_CAP`].
    reliable: Mutex<HashMap<String, ReliableEntry>>,
    /// Session-epoch source for reliable `OPEN`s. Seeded from wall-clock
    /// millis so epochs from before a restart (whose reliable map is gone)
    /// cannot collide with freshly assigned ones.
    epoch_source: AtomicU64,
    /// Durability health ([`DUR_OK`] / [`DUR_DEGRADED`] / [`DUR_FAILED`]),
    /// written by shard workers, read by `STATS`/`METRICS` and the
    /// fail-stop gate.
    dur_health: Arc<AtomicU8>,
}

/// What startup recovery rebuilt (see [`ScoringService::recover`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Sessions live after snapshot restore + WAL replay.
    pub restored_sessions: usize,
    /// WAL window records actually scored during replay.
    pub replayed_windows: usize,
    /// The committed epoch the restore started from, if any.
    pub epoch: Option<u64>,
}

/// Outcome of one committed epoch snapshot
/// (see [`ScoringService::snapshot_epoch`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpochSummary {
    pub epoch: u64,
    /// Live sessions checkpointed in the epoch.
    pub sessions: usize,
}

struct ShardOutcome {
    reports: Vec<SessionReport>,
    dropped: usize,
    closed_reports_dropped: usize,
}

/// Per-shard cap on retained reports of `Close`d sessions. Open/close churn
/// (or a hostile `OPEN`/`CLOSE` loop) must not grow server memory without
/// bound; past the cap the oldest-retired histories are dropped and only
/// counted ([`ServiceReport::closed_reports_dropped`]). Event *accounting*
/// ([`ServiceReport::total_events`]) is a counter and stays exact
/// regardless.
const MAX_RETAINED_CLOSED: usize = 4096;

impl ScoringService {
    /// Spawn the shard workers and start accepting events. Does **not**
    /// recover existing durability state — use [`ScoringService::recover`]
    /// when resuming from a populated durability directory.
    pub fn start(cfg: ServiceConfig) -> Self {
        // continue the epoch numbering even without a full recovery, so a
        // misuse of start() over live durability state cannot re-commit (and
        // prune away) an already-taken epoch number
        let next_epoch = cfg
            .durability
            .as_ref()
            .and_then(|d| snapshot::read_current(d).ok().flatten())
            .map_or(1, |e| e + 1);
        Self::start_with(cfg, Vec::new(), RecoveryReport::default(), next_epoch)
    }

    /// Start the service by recovering its durability directory: restore
    /// every session from the latest committed epoch's checkpoints, then
    /// replay the WAL tail through the normal scoring path (bit-identical to
    /// the crashed run — see `docs/DURABILITY.md`). Falls back to a plain
    /// [`ScoringService::start`] when durability is not configured.
    ///
    /// The restarting shard count need not match the one the directory was
    /// written under: replayed sessions re-route through `shard_of` with the
    /// *new* count (per-session order is safe — a session's whole history
    /// lives in one disk stream), and a rebound recovery commits a fresh
    /// epoch immediately so the old-layout segments are pruned before any
    /// new-layout WAL traffic lands.
    pub fn recover(cfg: ServiceConfig) -> anyhow::Result<Self> {
        let Some(dur) = cfg.durability.clone() else {
            return Ok(Self::start(cfg));
        };
        let shards = cfg.shards.max(1);
        let plan = recovery::plan(&dur, shards)?;
        let rebound = plan.disk_shards != shards;
        let mut report = RecoveryReport::default();
        let mut registries: Vec<SessionRegistry> =
            (0..shards).map(|_| SessionRegistry::new()).collect();
        // Each session's *disk* stream (the shard whose WAL carries its
        // records): EPOCH markers canonicalize exactly the sessions of their
        // own stream, reproducing the live barrier under any rebinding.
        let mut home: HashMap<String, usize> = HashMap::new();

        if let (Some(manifest), Some(dir)) = (&plan.manifest, &plan.epoch_dir) {
            report.epoch = Some(manifest.epoch);
            for meta in &manifest.sessions {
                let path = dir.join(format!("{}.ckpt", encode_session_id(&meta.id)));
                let state = checkpoint::load_with_policy(&path, cfg.policy)
                    .map_err(|e| anyhow::anyhow!("restore session {}: {e:#}", meta.id))?;
                home.insert(meta.id.clone(), meta.shard);
                if let Some(registry) = registries.get_mut(shard_of(&meta.id, shards)) {
                    registry.insert(SessionState::from_durable(state, meta, &cfg));
                }
            }
        }
        for (disk_shard, segments) in plan.segments.iter().enumerate() {
            for (_seq, path) in segments {
                for rec in WalReader::open(path)? {
                    report.replayed_windows +=
                        replay_routed(&mut registries, &mut home, rec, &cfg, disk_shard);
                }
            }
        }
        report.restored_sessions = registries.iter().map(SessionRegistry::len).sum();
        let next_epoch = plan.manifest.as_ref().map_or(1, |m| m.epoch + 1);
        let svc = Self::start_with(cfg, registries, report, next_epoch);
        if rebound {
            // the old-layout segments must never coexist with WAL traffic
            // written under the new routing (a later recovery would replay
            // them out of order), so the rebind is only durable once a
            // new-layout epoch commits and prunes them
            svc.snapshot_epoch().map_err(|e| {
                anyhow::anyhow!(
                    "rebind {} -> {shards} shards: post-rebind epoch commit: {e:#}",
                    plan.disk_shards
                )
            })?;
        }
        Ok(svc)
    }

    fn start_with(
        cfg: ServiceConfig,
        initial: Vec<SessionRegistry>,
        recovery: RecoveryReport,
        next_epoch: u64,
    ) -> Self {
        let shards = cfg.shards.max(1);
        crate::obs::note_shards(shards);
        let dur_health = Arc::new(AtomicU8::new(DUR_OK));
        let mut registries = initial;
        registries.resize_with(shards, SessionRegistry::new);
        let mut senders = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        let mut depths = Vec::with_capacity(shards);
        for (shard, registry) in registries.into_iter().enumerate() {
            let (tx, rx) = sync_channel::<ShardMsg>(cfg.channel_capacity.max(1));
            let worker_cfg = cfg.clone();
            let depth = Arc::new(AtomicUsize::new(0));
            let worker_depth = Arc::clone(&depth);
            let worker_health = Arc::clone(&dur_health);
            let handle = std::thread::Builder::new()
                .name(format!("finger-shard-{shard}"))
                .spawn(move || {
                    shard_worker(rx, worker_cfg, worker_depth, worker_health, shard, registry)
                })
                // finger-lint: allow(FL001): cold-start — no spawn, no service
                .expect("spawn shard worker");
            senders.push(tx);
            workers.push(handle);
            depths.push(depth);
        }
        let epoch_seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map_or(1, |d| d.as_millis() as u64)
            .max(1);
        Self {
            cfg,
            senders,
            workers,
            depths,
            submitted: AtomicUsize::new(0),
            start: Instant::now(),
            epoch: Mutex::new(next_epoch.max(1)),
            recovery,
            reliable: Mutex::new(HashMap::new()),
            epoch_source: AtomicU64::new(epoch_seed),
            dur_health,
        }
    }

    /// What startup recovery restored and replayed (all zeroes unless the
    /// service was started via [`ScoringService::recover`]).
    pub fn recovery(&self) -> &RecoveryReport {
        &self.recovery
    }

    fn reliable_map(&self) -> std::sync::MutexGuard<'_, HashMap<String, ReliableEntry>> {
        match self.reliable.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Resume a reliable session: `Some((epoch, acked))` when the client's
    /// non-zero `epoch` matches the tracked one (the session keeps its
    /// state; the client replays from `acked`). `None` means the caller
    /// must open fresh via [`ScoringService::reliable_begin`].
    pub fn reliable_resume(&self, id: &str, client_epoch: u64) -> Option<(u64, u64)> {
        if client_epoch == 0 {
            return None;
        }
        let map = self.reliable_map();
        let entry = map.get(id)?;
        (entry.epoch == client_epoch).then_some((entry.epoch, entry.acked))
    }

    /// Begin a fresh reliable session (new epoch, `acked = 0`). The caller
    /// still opens the session state through the normal open path.
    pub fn reliable_begin(&self, id: &str) -> u64 {
        let epoch = self.epoch_source.fetch_add(1, Ordering::Relaxed);
        let mut map = self.reliable_map();
        if map.len() >= RELIABLE_CAP && !map.contains_key(id) {
            // evict one arbitrary session; it degrades to fresh-epoch
            // semantics on its next reliable OPEN (reset, never duplicated)
            if let Some(victim) = map.keys().next().cloned() {
                map.remove(&victim);
            }
        }
        map.insert(id.to_string(), ReliableEntry { epoch, acked: 0 });
        epoch
    }

    /// Classify a reliable write's sequence number against the session's
    /// `acked` high-water mark.
    pub fn reliable_seq(&self, id: &str, seq: u64) -> SeqOutcome {
        let map = self.reliable_map();
        let Some(entry) = map.get(id) else { return SeqOutcome::Gap { acked: 0 } };
        if seq <= entry.acked {
            SeqOutcome::Duplicate { acked: entry.acked }
        } else if seq == entry.acked + 1 {
            SeqOutcome::Apply
        } else {
            SeqOutcome::Gap { acked: entry.acked }
        }
    }

    /// Record `seq` as applied (monotone: an older ack never rewinds).
    pub fn reliable_ack(&self, id: &str, seq: u64) {
        if let Some(entry) = self.reliable_map().get_mut(id) {
            entry.acked = entry.acked.max(seq);
        }
    }

    /// Drop a session's reliable state (close, or an unreliable re-open).
    pub fn reliable_forget(&self, id: &str) {
        self.reliable_map().remove(id);
    }

    /// Current durability health byte ([`DUR_OK`] / [`DUR_DEGRADED`] /
    /// [`DUR_FAILED`]).
    pub fn durability_health(&self) -> u8 {
        self.dur_health.load(Ordering::Relaxed)
    }

    /// Durability health as the `STATS` wire word: `off` (not configured),
    /// `on`, `degraded`, or `failed`.
    pub fn durability_status(&self) -> &'static str {
        if self.cfg.durability.is_none() {
            return "off";
        }
        match self.durability_health() {
            DUR_DEGRADED => "degraded",
            DUR_FAILED => "failed",
            _ => "on",
        }
    }

    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Deterministic shard a session's events flow through.
    pub fn shard_for(&self, session_id: &str) -> usize {
        shard_of(session_id, self.senders.len())
    }

    /// (Re)open a session with an initial graph. Ordered with respect to
    /// subsequent `submit`s for the same id (same FIFO shard channel).
    pub fn open_session(&self, id: &str, initial: Graph) -> Result<(), SubmitError> {
        self.open_session_state(id, FingerState::with_policy(initial, self.cfg.policy))
    }

    /// (Re)open a session resuming from an existing incremental state.
    pub fn open_session_state(&self, id: &str, state: FingerState) -> Result<(), SubmitError> {
        self.send(ShardMsg::Open { id: id.to_string(), state }).map(|_| ())
    }

    /// Route one event to `id`'s shard. Blocks while that shard's bounded
    /// queue is full (backpressure) — it never drops.
    pub fn submit(&self, id: &str, ev: StreamEvent) -> Result<(), SubmitError> {
        let shard = self.send(ShardMsg::Event { id: id.to_string(), ev })?;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        crate::obs::shard_events_add(shard, 1);
        Ok(())
    }

    /// Route a whole event stream to one session; returns the event count.
    pub fn submit_all<I>(&self, id: &str, events: I) -> Result<usize, SubmitError>
    where
        I: IntoIterator<Item = StreamEvent>,
    {
        let mut n = 0;
        for ev in events {
            self.submit(id, ev)?;
            n += 1;
        }
        Ok(n)
    }

    /// Route a batch of events to `id`'s shard as a single message —
    /// identical semantics to submitting each event in order, at a fraction
    /// of the routing/channel overhead. Returns the batch size.
    pub fn submit_batch(&self, id: &str, events: Vec<StreamEvent>) -> Result<usize, SubmitError> {
        let n = events.len();
        if n == 0 {
            return Ok(0);
        }
        let shard = self.send(ShardMsg::Batch { id: id.to_string(), events })?;
        self.submitted.fetch_add(n, Ordering::Relaxed);
        crate::obs::shard_events_add(shard, n as u64);
        Ok(n)
    }

    /// Non-blocking [`submit`](Self::submit): fails with
    /// [`SubmitError::WouldBlock`] instead of waiting when `id`'s shard
    /// queue is full, so an ingest thread multiplexing many sessions (e.g. a
    /// network connection reader) is never wedged by one stalled shard.
    pub fn try_submit(&self, id: &str, ev: StreamEvent) -> Result<(), SubmitError> {
        let shard =
            self.try_send(ShardMsg::Event { id: id.to_string(), ev }).map_err(|(_, e)| e)?;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        crate::obs::shard_events_add(shard, 1);
        Ok(())
    }

    /// Non-blocking [`submit_batch`](Self::submit_batch). On failure the
    /// events are handed back so the caller can retry without cloning.
    pub fn try_submit_batch(
        &self,
        id: &str,
        events: Vec<StreamEvent>,
    ) -> Result<usize, (Vec<StreamEvent>, SubmitError)> {
        let n = events.len();
        if n == 0 {
            return Ok(0);
        }
        match self.try_send(ShardMsg::Batch { id: id.to_string(), events }) {
            Ok(shard) => {
                self.submitted.fetch_add(n, Ordering::Relaxed);
                crate::obs::shard_events_add(shard, n as u64);
                Ok(n)
            }
            Err((ShardMsg::Batch { events, .. }, e)) => Err((events, e)),
            Err((_, e)) => Err((Vec::new(), e)), // try_send echoes the variant
        }
    }

    /// Non-blocking [`open_session_state`](Self::open_session_state): fails
    /// with [`SubmitError::WouldBlock`] when the shard's queue is full,
    /// handing the state back so the caller can retry without rebuilding it.
    pub fn try_open_session_state(
        &self,
        id: &str,
        state: FingerState,
    ) -> Result<(), (FingerState, SubmitError)> {
        match self.try_send(ShardMsg::Open { id: id.to_string(), state }) {
            Ok(_) => Ok(()),
            Err((ShardMsg::Open { state, .. }, e)) => Err((state, e)),
            // finger-lint: allow(FL001): try_send echoes the sent variant back
            Err(_) => unreachable!("try_send echoes the sent message variant"),
        }
    }

    /// Point-in-time stats for a live session (windows scored, latest
    /// JSdist, H̃, anomaly count, pending events). `Ok(None)` when the shard
    /// has no such session. The query rides the same FIFO channel as events,
    /// so it reflects every event this caller submitted before it. Blocks
    /// while the shard's queue is full, like `submit`.
    pub fn query(&self, id: &str) -> Result<Option<SessionSnapshot>, SubmitError> {
        // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
        let (tx, rx) = channel();
        self.send(ShardMsg::Query { id: id.to_string(), reply: tx })?;
        rx.recv().map_err(|_| SubmitError::Closed { shard: self.shard_for(id) })
    }

    /// Non-blocking [`query`](Self::query): fails with
    /// [`SubmitError::WouldBlock`] instead of waiting when the shard's queue
    /// is full. Once enqueued, the reply wait is bounded by the work already
    /// queued (shard workers never block on anything themselves).
    pub fn try_query(&self, id: &str) -> Result<Option<SessionSnapshot>, SubmitError> {
        // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
        let (tx, rx) = channel();
        self.try_send(ShardMsg::Query { id: id.to_string(), reply: tx })
            .map_err(|(_, e)| e)?;
        rx.recv().map_err(|_| SubmitError::Closed { shard: self.shard_for(id) })
    }

    /// Retire session `id`: flush its trailing partial window, free the
    /// shard state and return the final [`SessionSnapshot`] (`None` when the
    /// shard knows no such session — the wire maps that to
    /// `ERR unknown-session`). The close rides the same FIFO channel as
    /// events, so it observes everything this caller submitted before it.
    /// The retired session's report still counts in the final
    /// [`ServiceReport`] (its events were genuinely scored, retained up to a
    /// per-shard cap — see [`ServiceReport::closed_reports_dropped`]); it is
    /// simply no longer live, so later events for the id hit the
    /// auto-create/drop path and `finish` does not checkpoint it. Blocks
    /// while the shard's queue is full, like `submit`.
    pub fn close_session(&self, id: &str) -> Result<Option<SessionSnapshot>, SubmitError> {
        // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
        let (tx, rx) = channel();
        self.send(ShardMsg::Close { id: id.to_string(), reply: tx })?;
        rx.recv().map_err(|_| SubmitError::Closed { shard: self.shard_for(id) })
    }

    /// Non-blocking [`close_session`](Self::close_session): fails with
    /// [`SubmitError::WouldBlock`] instead of waiting when the shard's queue
    /// is full.
    pub fn try_close_session(
        &self,
        id: &str,
    ) -> Result<Option<SessionSnapshot>, SubmitError> {
        // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
        let (tx, rx) = channel();
        self.try_send(ShardMsg::Close { id: id.to_string(), reply: tx })
            .map_err(|(_, e)| e)?;
        rx.recv().map_err(|_| SubmitError::Closed { shard: self.shard_for(id) })
    }

    /// Messages currently in flight per shard (queued plus being processed).
    /// A persistently deep shard signals a hot session set; the `STATS`
    /// protocol verb surfaces this to operators.
    pub fn queue_depths(&self) -> Vec<usize> {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed)).collect()
    }

    /// Events accepted so far across all sessions.
    pub fn events_submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Milliseconds since the service started accepting events (surfaced by
    /// the `STATS`/`METRICS` protocol verbs and the obs snapshot).
    pub fn uptime_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Re-open every `<id>.ckpt` session found in `dir` (written by a prior
    /// run's `finish` with `checkpoint_dir` set). Returns how many sessions
    /// were restored.
    pub fn restore_sessions(&self, dir: impl AsRef<Path>) -> anyhow::Result<usize> {
        let mut restored = 0;
        let mut entries: Vec<_> =
            std::fs::read_dir(dir.as_ref())?.collect::<Result<Vec<_>, _>>()?;
        entries.sort_by_key(|e| e.path());
        for entry in entries {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("ckpt") {
                continue;
            }
            let id = match path
                .file_stem()
                .and_then(|s| s.to_str())
                .and_then(super::session::decode_session_id)
            {
                Some(s) => s,
                None => continue, // not written by our encoder
            };
            let state = checkpoint::load_with_policy(&path, self.cfg.policy)?;
            self.open_session_state(&id, state)
                .map_err(|e| anyhow::anyhow!("restore {id}: {e}"))?;
            restored += 1;
        }
        Ok(restored)
    }

    /// Cut one epoch snapshot online, without draining: broadcast the
    /// barrier through every shard's FIFO channel, collect the per-shard
    /// [`EpochCut`]s, and commit the manifest atomically. Epochs are
    /// serialized — one commit at a time — and the numbering continues past
    /// the recovered epoch. Errors when durability is not configured.
    pub fn snapshot_epoch(&self) -> anyhow::Result<EpochSummary> {
        let Some(dur) = self.cfg.durability.clone() else {
            anyhow::bail!("durability is not configured (no [durability] dir)");
        };
        let mut next = match self.epoch.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        let epoch = *next;
        let tmp = snapshot::prepare_epoch_tmp(&dur, epoch)?;
        // finger-lint: allow(FL004): rendezvous replies; one per shard, then dropped
        let (tx, rx) = channel();
        for (shard, sender) in self.senders.iter().enumerate() {
            let msg = ShardMsg::Epoch { dir: tmp.clone(), epoch, reply: tx.clone() };
            if let Some(depth) = self.depths.get(shard) {
                depth.fetch_add(1, Ordering::Relaxed);
                if sender.send(msg).is_err() {
                    depth.fetch_sub(1, Ordering::Relaxed);
                    anyhow::bail!("shard {shard} is gone; epoch {epoch} aborted");
                }
            }
        }
        drop(tx);
        let mut cuts = Vec::with_capacity(self.senders.len());
        for _ in 0..self.senders.len() {
            let cut = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("a shard worker died during epoch {epoch}"))??;
            cuts.push(cut);
        }
        cuts.sort_by_key(|c| c.shard);
        let manifest = snapshot::commit_epoch(&dur, epoch, &cuts)?;
        *next = epoch + 1;
        crate::obs::Counter::SnapshotEpochs.inc();
        // every shard rotated onto a fresh, healthy log and the epoch is
        // durable: a fail-stop latch is cleared (degrade mode never reaches
        // here — a WAL-less shard fails its cut)
        self.dur_health.store(DUR_OK, Ordering::Relaxed);
        Ok(EpochSummary { epoch, sessions: manifest.sessions.len() })
    }

    fn shard_of_msg(&self, msg: &ShardMsg) -> usize {
        let id = match msg {
            ShardMsg::Open { id, .. }
            | ShardMsg::Event { id, .. }
            | ShardMsg::Batch { id, .. }
            | ShardMsg::Query { id, .. }
            | ShardMsg::Close { id, .. } => id,
            // broadcast by snapshot_epoch to every shard directly, never
            // routed through send/try_send
            ShardMsg::Epoch { .. } => return 0,
        };
        shard_of(id, self.senders.len())
    }

    /// Route `msg` to its shard, returning the shard index on success so
    /// callers can attribute the send in the metrics registry.
    fn send(&self, msg: ShardMsg) -> Result<usize, SubmitError> {
        let shard = self.shard_of_msg(&msg);
        // finger-lint: allow(FL001): shard_of bounds the index by senders.len()
        let (sender, depth) = (&self.senders[shard], &self.depths[shard]);
        // count before sending so a blocked send is visible as queue depth
        depth.fetch_add(1, Ordering::Relaxed);
        sender.send(msg).map(|()| shard).map_err(|_| {
            depth.fetch_sub(1, Ordering::Relaxed);
            SubmitError::Closed { shard }
        })
    }

    fn try_send(&self, msg: ShardMsg) -> Result<usize, (ShardMsg, SubmitError)> {
        let shard = self.shard_of_msg(&msg);
        if crate::fault::fire(crate::fault::Failpoint::ShardSubmit) {
            // injected backpressure: indistinguishable from a full queue, so
            // the whole park/shed/retry machinery above exercises for real
            crate::obs::shard_would_block(shard);
            return Err((msg, SubmitError::WouldBlock { shard }));
        }
        // finger-lint: allow(FL001): shard_of bounds the index by senders.len()
        let (sender, depth) = (&self.senders[shard], &self.depths[shard]);
        depth.fetch_add(1, Ordering::Relaxed);
        sender.try_send(msg).map(|()| shard).map_err(|e| {
            depth.fetch_sub(1, Ordering::Relaxed);
            match e {
                TrySendError::Full(m) => {
                    crate::obs::shard_would_block(shard);
                    (m, SubmitError::WouldBlock { shard })
                }
                TrySendError::Disconnected(m) => (m, SubmitError::Closed { shard }),
            }
        })
    }

    /// Close the ingest side, drain every shard (flushing partial windows,
    /// checkpointing when configured) and aggregate the results.
    pub fn finish(self) -> ServiceReport {
        let Self {
            cfg,
            senders,
            workers,
            submitted,
            start,
            depths: _,
            epoch: _,
            recovery,
            reliable: _,
            epoch_source: _,
            dur_health: _,
        } = self;
        drop(senders); // workers' receive loops end once the queues drain
        let mut sessions = Vec::new();
        let mut dropped_events = 0;
        let mut closed_reports_dropped = 0;
        for worker in workers {
            match worker.join() {
                Ok(outcome) => {
                    sessions.extend(outcome.reports);
                    dropped_events += outcome.dropped;
                    closed_reports_dropped += outcome.closed_reports_dropped;
                }
                // a panicked shard lost its session reports, but the drain
                // must still surface what the surviving shards scored
                Err(_) => {
                    eprintln!("finger-service: a shard worker panicked; its reports are lost");
                }
            }
        }
        sessions.sort_by(|a, b| a.id.cmp(&b.id));
        let wall_secs = start.elapsed().as_secs_f64();
        let total_events = submitted.into_inner();
        ServiceReport {
            throughput: total_events as f64 / wall_secs.max(1e-12),
            total_events,
            dropped_events,
            closed_reports_dropped,
            wall_secs,
            shards: cfg.shards.max(1),
            restored_sessions: recovery.restored_sessions,
            replayed_windows: recovery.replayed_windows,
            sessions,
        }
    }
}

fn shard_worker(
    rx: Receiver<ShardMsg>,
    cfg: ServiceConfig,
    depth: Arc<AtomicUsize>,
    health: Arc<AtomicU8>,
    shard: usize,
    initial: SessionRegistry,
) -> ShardOutcome {
    let on_error = cfg.durability.as_ref().map(|d| d.on_error).unwrap_or_default();
    let mut registry = initial;
    for _ in 0..registry.len() {
        crate::obs::Gauge::SvcSessions.inc(); // recovered sessions are live
    }
    let mut wal = cfg.durability.as_ref().and_then(|dur| {
        match WalWriter::open(&dur.wal_dir(), shard, dur.fsync, dur.segment_bytes) {
            Ok(w) => Some(w),
            Err(e) => {
                eprintln!("wal[shard {shard}]: open failed: {e}; running without WAL");
                None
            }
        }
    });
    let mut dropped = 0;
    // reports of sessions retired via Close: their events were scored, so
    // they still count in the final ServiceReport — they are just no longer
    // live (not queryable, not checkpointed at finish). Retention is capped
    // so close churn can't grow memory without bound.
    let mut closed: Vec<SessionReport> = Vec::new();
    let mut closed_reports_dropped = 0usize;
    let route = |registry: &mut SessionRegistry,
                     dropped: &mut usize,
                     wal: &mut Option<WalWriter>,
                     id: String,
                     events: &mut dyn Iterator<Item = StreamEvent>| {
        if !registry.contains(&id) && cfg.auto_create_sessions {
            registry.insert(SessionState::new(id.clone(), Graph::new(0), &cfg));
            crate::obs::Gauge::SvcSessions.inc();
        }
        match registry.get_mut(&id) {
            Some(session) => {
                for ev in events {
                    if session.on_event_durable(ev, wal.as_mut()) {
                        crate::obs::shard_window(shard);
                    }
                }
            }
            // auto-create disabled and the id is unknown: count, don't panic
            None => *dropped += events.count(),
        }
    };
    for msg in rx {
        match msg {
            ShardMsg::Open { id, state } => {
                if !registry.contains(&id) {
                    crate::obs::Gauge::SvcSessions.inc();
                }
                if let Some(w) = wal.as_mut() {
                    w.append_open(&id, state.graph());
                }
                registry.insert(SessionState::from_finger_state(id.clone(), state, &cfg));
                if wal.is_some() {
                    // recovery rebuilds an OPEN purely from its logged graph,
                    // so force the live state onto that same canonical form
                    // (a no-op for states freshly built from a graph, which
                    // is every state the public open paths produce)
                    if let Some(session) = registry.get_mut(&id) {
                        session.canonicalize();
                    }
                }
            }
            ShardMsg::Event { id, ev } => {
                route(&mut registry, &mut dropped, &mut wal, id, &mut std::iter::once(ev));
            }
            ShardMsg::Batch { id, events } => {
                route(&mut registry, &mut dropped, &mut wal, id, &mut events.into_iter());
            }
            ShardMsg::Query { id, reply } => {
                // the querying side may have hung up; that's its business
                let _ = reply.send(registry.get(&id).map(SessionState::snapshot));
            }
            ShardMsg::Close { id, reply } => {
                let snapshot = match registry.remove(&id) {
                    Some(mut session) => {
                        crate::obs::Gauge::SvcSessions.dec();
                        if session.flush_durable(wal.as_mut()) {
                            // the final snapshot scores any open window
                            crate::obs::shard_window(shard);
                        }
                        if let Some(w) = wal.as_mut() {
                            w.append_close(&id);
                        }
                        let snap = session.snapshot();
                        if closed.len() < MAX_RETAINED_CLOSED {
                            closed.push(session.into_report());
                        } else {
                            closed_reports_dropped += 1;
                        }
                        Some(snap)
                    }
                    None => None,
                };
                let _ = reply.send(snapshot);
            }
            ShardMsg::Epoch { dir, epoch, reply } => {
                let _ = reply.send(cut_epoch(&mut registry, &mut wal, &dir, epoch, shard));
            }
        }
        // a WAL writer that latched on an IO error during this message is
        // handled per `[durability] on_error` before the next one
        if wal.as_ref().is_some_and(|w| !w.healthy()) {
            match on_error {
                OnError::Degrade => {
                    // drop the log and keep scoring; the degraded flag rides
                    // STATS/METRICS until a restart re-opens the WAL
                    wal = None;
                    health.store(DUR_DEGRADED, Ordering::Relaxed);
                    crate::obs::Counter::Degraded.inc();
                    eprintln!(
                        "wal[shard {shard}]: write failed; degrading to non-durable scoring"
                    );
                }
                OnError::FailStop => {
                    if health.swap(DUR_FAILED, Ordering::Relaxed) != DUR_FAILED {
                        eprintln!(
                            "wal[shard {shard}]: write failed; refusing new writes \
                             (on_error=fail_stop) until an epoch cut restores the log"
                        );
                    }
                }
            }
        }
        // decrement only after the message is fully processed, so depth
        // really is "queued + being processed": a shard grinding through a
        // huge batch must not look idle to STATS / rebalancing heuristics
        depth.fetch_sub(1, Ordering::Relaxed);
    }
    // ingest closed: flush, checkpoint, report
    let mut reports = closed;
    for mut session in registry.into_sessions() {
        crate::obs::Gauge::SvcSessions.dec();
        if session.flush_durable(wal.as_mut()) {
            crate::obs::shard_window(shard);
        }
        if let Some(dir) = &cfg.checkpoint_dir {
            if let Err(e) = session.checkpoint_into(dir) {
                eprintln!("checkpoint session {}: {e:#}", session.id());
            }
        }
        reports.push(session.into_report());
    }
    if let Some(w) = wal.as_mut() {
        w.sync(); // drain-time flush windows must hit stable storage
    }
    ShardOutcome { reports, dropped, closed_reports_dropped }
}

/// Execute the epoch barrier on one shard: rotate the WAL so a fresh segment
/// leads with the EPOCH marker, canonicalize every live session (exactly
/// what replay does when it meets that marker), then checkpoint each into
/// the epoch's staging directory and report the cut. Canonicalization runs
/// to completion over all sessions *before* any fallible checkpoint write,
/// so a failed cut still leaves the live states consistent with the marker.
fn cut_epoch(
    registry: &mut SessionRegistry,
    wal: &mut Option<WalWriter>,
    dir: &Path,
    epoch: u64,
    shard: usize,
) -> anyhow::Result<EpochCut> {
    let next_seq = match wal.as_mut() {
        Some(w) => w.rotate_epoch(epoch)?,
        None => anyhow::bail!("shard {shard} has no WAL writer; epoch {epoch} aborted"),
    };
    let mut failed: Option<String> = None;
    for session in registry.sessions_mut() {
        if !session.canonicalize() && failed.is_none() {
            failed = Some(session.id().to_string());
        }
    }
    if let Some(id) = failed {
        anyhow::bail!("canonicalize session {id} at epoch {epoch}");
    }
    let mut sessions = Vec::with_capacity(registry.len());
    for session in registry.sessions_mut() {
        session.checkpoint_into(dir).map_err(|e| {
            anyhow::anyhow!("checkpoint session {} at epoch {epoch}: {e:#}", session.id())
        })?;
        sessions.push(session.durable_meta(shard));
    }
    Ok(EpochCut { shard, next_seq, sessions })
}

/// Apply one record from disk stream `disk_shard`, routing its session to
/// the registry `shard_of(id, new_shards)` picks — the seam that lets a
/// directory written under one shard count restart under another. `home`
/// tracks each session's disk stream (manifest-seeded, then first-touch) so
/// an `EPOCH` marker canonicalizes exactly the sessions whose records share
/// its stream, reproducing the live barrier under any rebinding. Returns
/// windows scored (0 or 1).
fn replay_routed(
    registries: &mut [SessionRegistry],
    home: &mut HashMap<String, usize>,
    rec: WalRecord,
    cfg: &ServiceConfig,
    disk_shard: usize,
) -> usize {
    if matches!(rec, WalRecord::Epoch { .. }) {
        for registry in registries.iter_mut() {
            for session in registry.sessions_mut() {
                if home.get(session.id()).copied() == Some(disk_shard) {
                    session.canonicalize();
                }
            }
        }
        return 0;
    }
    let id = match &rec {
        WalRecord::Open { id, .. }
        | WalRecord::Window { id, .. }
        | WalRecord::Close { id } => id.clone(),
        WalRecord::Epoch { .. } => return 0, // handled above
    };
    home.entry(id.clone()).or_insert(disk_shard);
    let slot = shard_of(&id, registries.len().max(1));
    match registries.get_mut(slot) {
        Some(registry) => replay_record(registry, rec, cfg),
        None => 0,
    }
}

/// Apply one replayed WAL record to a shard's recovered registry, mirroring
/// the live worker's handling of the message that produced the record.
/// Returns the number of windows scored (0 or 1) for the recovery report.
fn replay_record(registry: &mut SessionRegistry, rec: WalRecord, cfg: &ServiceConfig) -> usize {
    match rec {
        WalRecord::Open { id, nodes, edges } => {
            let mut g = Graph::new(nodes);
            for (i, j, w) in edges {
                // decoded edges satisfy i < j; an endpoint past `nodes`
                // would mean a corrupt-but-CRC-valid record, so grow rather
                // than reach Graph's bounds assert
                if j as usize >= g.num_nodes() {
                    g.ensure_nodes(j as usize + 1);
                }
                g.set_weight(i, j, w);
            }
            // the live Open canonicalized right after insert; building from
            // the logged graph lands on that same canonical state
            registry.insert(SessionState::from_finger_state(
                id,
                FingerState::with_policy(g, cfg.policy),
                cfg,
            ));
            0
        }
        WalRecord::Window { id, window_seq, n_events, delta } => {
            if !registry.contains(&id) {
                if !cfg.auto_create_sessions {
                    return 0; // mirrors the live drop path
                }
                registry.insert(SessionState::new(id.clone(), Graph::new(0), cfg));
            }
            match registry.get_mut(&id) {
                Some(session) if session.replay_window(window_seq, n_events, &delta) => 1,
                _ => 0,
            }
        }
        WalRecord::Close { id } => {
            registry.remove(&id);
            0
        }
        WalRecord::Epoch { .. } => {
            // the live server canonicalized every session at exactly this
            // stream position; reproduce it (idempotent, so a marker replayed
            // over already-canonical restored states is a no-op)
            for session in registry.sessions_mut() {
                session.canonicalize();
            }
            0
        }
    }
}

/// Aggregate outcome across all shards and sessions.
#[derive(Debug)]
pub struct ServiceReport {
    /// Per-session reports, sorted by session id.
    pub sessions: Vec<SessionReport>,
    /// Events accepted through `submit` across all sessions.
    pub total_events: usize,
    /// Events for unknown sessions dropped because `auto_create_sessions`
    /// was off.
    pub dropped_events: usize,
    /// `Close`d-session reports discarded past the per-shard retention cap
    /// (close churn must not grow memory unboundedly); their events remain
    /// counted in `total_events`.
    pub closed_reports_dropped: usize,
    pub wall_secs: f64,
    /// Accepted events per second, aggregated over the whole run.
    pub throughput: f64,
    pub shards: usize,
    /// Sessions restored by startup recovery (0 for a fresh start).
    pub restored_sessions: usize,
    /// WAL windows replayed through the scorer by startup recovery.
    pub replayed_windows: usize,
}

impl ServiceReport {
    pub fn session(&self, id: &str) -> Option<&SessionReport> {
        self.sessions.iter().find(|s| s.id == id)
    }

    pub fn total_windows(&self) -> usize {
        self.sessions.iter().map(|s| s.records.len()).sum()
    }

    pub fn total_anomalies(&self) -> usize {
        self.sessions.iter().map(|s| s.anomalies.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_session_basic_flow() {
        let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
        svc.open_session("a", Graph::new(4)).unwrap();
        svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
        svc.submit("a", StreamEvent::Tick).unwrap();
        let report = svc.finish();
        assert_eq!(report.total_events, 2);
        assert_eq!(report.dropped_events, 0);
        let s = report.session("a").unwrap();
        assert_eq!(s.records.len(), 1);
        assert_eq!(s.edges, 1);
    }

    #[test]
    fn auto_create_off_drops_and_counts() {
        let cfg = ServiceConfig { shards: 1, auto_create_sessions: false, ..Default::default() };
        let svc = ScoringService::start(cfg);
        svc.open_session("known", Graph::new(2)).unwrap();
        svc.submit("known", StreamEvent::Tick).unwrap();
        svc.submit("unknown", StreamEvent::Tick).unwrap();
        let report = svc.finish();
        assert_eq!(report.sessions.len(), 1);
        assert_eq!(report.dropped_events, 1);
        assert_eq!(report.total_events, 2);
    }

    #[test]
    fn try_submit_reports_would_block_and_recovers() {
        // capacity-1 queue, no consumer progress guaranteed: fill it with a
        // blocking submit, then try_submit must fail fast with WouldBlock
        // once the queue is full (never hang), and a blocking submit after
        // the worker drains must still succeed.
        let cfg = ServiceConfig { shards: 1, channel_capacity: 1, ..Default::default() };
        let svc = ScoringService::start(cfg);
        svc.open_session("a", Graph::new(4)).unwrap();
        // occupy the worker with one long batch so the queue stays full
        let busy: Vec<StreamEvent> = (0..200_000u32)
            .map(|k| StreamEvent::EdgeDelta { i: k % 4, j: (k + 1) % 4, dw: 1e-6 })
            .collect();
        svc.submit_batch("a", busy).unwrap();
        let mut saw_would_block = false;
        for _ in 0..10_000 {
            match svc.try_submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 0.01 }) {
                Ok(()) => {}
                Err(SubmitError::WouldBlock { shard }) => {
                    assert_eq!(shard, 0);
                    saw_would_block = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_would_block, "a capacity-1 queue must eventually refuse");
        // batch variant hands the events back for a clone-free retry
        let mut evs = vec![StreamEvent::Tick];
        loop {
            match svc.try_submit_batch("a", evs) {
                Ok(n) => {
                    assert_eq!(n, 1);
                    break;
                }
                Err((back, SubmitError::WouldBlock { .. })) => {
                    assert_eq!(back.len(), 1);
                    evs = back;
                    std::thread::yield_now();
                }
                Err((_, e)) => panic!("unexpected {e}"),
            }
        }
        let report = svc.finish();
        assert_eq!(report.total_events, report.session("a").unwrap().events);
    }

    #[test]
    fn queue_depths_drain_to_zero_and_query_sees_prior_events() {
        let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
        svc.open_session("a", Graph::new(4)).unwrap();
        svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
        svc.submit("a", StreamEvent::Tick).unwrap();
        // query is FIFO-ordered behind the events above
        let snap = svc.query("a").unwrap().expect("session exists");
        assert_eq!(snap.id, "a");
        assert_eq!(snap.windows, 1);
        assert_eq!(snap.events, 2);
        assert!(snap.last_jsdist.is_some());
        assert_eq!(snap.edges, 1);
        assert_eq!(snap.pending_events, 0);
        assert_eq!(svc.query("missing").unwrap(), None);
        assert_eq!(svc.queue_depths().len(), 2);
        // the query round-trip means everything queued ahead of it was
        // consumed; the query message's own depth decrement lands just
        // after the reply, so poll briefly instead of asserting instantly
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let depths = svc.queue_depths();
            if depths[svc.shard_for("a")] == 0 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "depth never drained: {depths:?}");
            std::thread::yield_now();
        }
        assert_eq!(svc.events_submitted(), 2);
        svc.finish();
    }

    #[test]
    fn close_session_returns_final_snapshot_and_frees_state() {
        let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
        svc.open_session("a", Graph::new(4)).unwrap();
        svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
        svc.submit("a", StreamEvent::Tick).unwrap();
        // trailing partial window: flushed into the final snapshot
        svc.submit("a", StreamEvent::EdgeDelta { i: 1, j: 2, dw: 2.0 }).unwrap();
        let snap = svc.close_session("a").unwrap().expect("session was live");
        assert_eq!(snap.windows, 2, "close flushes the open window");
        assert_eq!(snap.events, 3);
        assert_eq!(snap.edges, 2);
        assert_eq!(snap.pending_events, 0);
        // the session is gone: a second close and a query both miss
        assert_eq!(svc.close_session("a").unwrap(), None);
        assert_eq!(svc.query("a").unwrap(), None);
        // ...but its scored history still reaches the final report
        let report = svc.finish();
        let s = report.session("a").expect("closed session still reported");
        assert_eq!(s.records.len(), 2);
        assert_eq!(s.events, 3);
        assert_eq!(report.total_events, 3);
    }

    use crate::durability::{DurabilityConfig, FsyncPolicy};
    use std::path::PathBuf;

    fn durable_cfg(tag: &str) -> (ServiceConfig, PathBuf) {
        let root =
            std::env::temp_dir().join(format!("finger_engine_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let mut dur = DurabilityConfig::new(&root);
        dur.fsync = FsyncPolicy::Always;
        let cfg = ServiceConfig { shards: 2, durability: Some(dur), ..Default::default() };
        (cfg, root)
    }

    /// Deterministic two-session load; ends on explicit ticks so no events
    /// are pending (pending partials are not durable by design).
    fn feed(svc: &ScoringService, seed: u32, n: u32) {
        for k in 0..n {
            let i = (k * 7 + seed) % 6;
            let j = i + 1 + (k % 3);
            let id = if k % 2 == 0 { "a" } else { "b" };
            let dw = 0.1 + f64::from(k % 5) * 0.3;
            svc.submit(id, StreamEvent::EdgeDelta { i, j, dw }).unwrap();
            if k % 7 == 6 {
                svc.submit(id, StreamEvent::Tick).unwrap();
            }
        }
        svc.submit("a", StreamEvent::Tick).unwrap();
        svc.submit("b", StreamEvent::Tick).unwrap();
    }

    fn assert_snapshots_bit_identical(got: &SessionSnapshot, want: &SessionSnapshot) {
        assert_eq!(got.htilde.to_bits(), want.htilde.to_bits(), "{}: htilde bits", got.id);
        assert_eq!(
            got.last_jsdist.map(f64::to_bits),
            want.last_jsdist.map(f64::to_bits),
            "{}: jsdist bits",
            got.id
        );
        assert_eq!(got, want);
    }

    #[test]
    fn recover_after_simulated_crash_is_bit_identical_to_uninterrupted_run() {
        let (cfg_ref, root_ref) = durable_cfg("ref");
        let (cfg_crash, root_crash) = durable_cfg("crash");
        // identical load on both runs, an epoch cut mid-stream in each; the
        // crash run is then abandoned without drain (mem::forget: no final
        // flush, no checkpoint — only the WAL + epoch survive, like kill -9)
        let run = |cfg: ServiceConfig, crash: bool| -> Vec<SessionSnapshot> {
            let svc = ScoringService::recover(cfg).unwrap();
            svc.open_session("a", Graph::new(4)).unwrap();
            svc.open_session("b", Graph::new(4)).unwrap();
            feed(&svc, 1, 120);
            let cut = svc.snapshot_epoch().unwrap();
            assert_eq!(cut.epoch, 1);
            assert_eq!(cut.sessions, 2);
            feed(&svc, 2, 90);
            let snaps =
                vec![svc.query("a").unwrap().unwrap(), svc.query("b").unwrap().unwrap()];
            if crash {
                std::mem::forget(svc);
            } else {
                svc.finish();
            }
            snaps
        };
        let want = run(cfg_ref, false);
        let live = run(cfg_crash.clone(), true);
        for (l, w) in live.iter().zip(&want) {
            assert_snapshots_bit_identical(l, w); // same inputs, same trajectory
        }

        let svc = ScoringService::recover(cfg_crash).unwrap();
        let rep = svc.recovery().clone();
        assert_eq!(rep.restored_sessions, 2);
        assert_eq!(rep.epoch, Some(1));
        assert!(rep.replayed_windows > 0, "post-epoch windows must replay");
        for want_snap in &want {
            let got = svc.query(&want_snap.id).unwrap().unwrap();
            assert_snapshots_bit_identical(&got, want_snap);
        }
        let report = svc.finish();
        assert_eq!(report.restored_sessions, 2);
        assert_eq!(report.replayed_windows, rep.replayed_windows);
        std::fs::remove_dir_all(root_ref).ok();
        std::fs::remove_dir_all(root_crash).ok();
    }

    #[test]
    fn recover_replays_wal_without_any_committed_epoch() {
        let (cfg, root) = durable_cfg("noepoch");
        let svc = ScoringService::recover(cfg.clone()).unwrap();
        assert_eq!(svc.recovery(), &RecoveryReport::default());
        svc.open_session("a", Graph::new(4)).unwrap();
        // "b" is never opened: exercises the auto-create path on replay too
        feed(&svc, 3, 60);
        let want =
            vec![svc.query("a").unwrap().unwrap(), svc.query("b").unwrap().unwrap()];
        std::mem::forget(svc);

        let svc = ScoringService::recover(cfg).unwrap();
        assert_eq!(svc.recovery().epoch, None);
        assert!(svc.recovery().replayed_windows > 0);
        for want_snap in &want {
            let got = svc.query(&want_snap.id).unwrap().unwrap();
            assert_snapshots_bit_identical(&got, want_snap);
        }
        svc.finish();
        std::fs::remove_dir_all(root).ok();
    }

    #[test]
    fn closed_sessions_stay_closed_across_recovery() {
        let (cfg, root) = durable_cfg("close");
        let svc = ScoringService::recover(cfg.clone()).unwrap();
        svc.open_session("a", Graph::new(4)).unwrap();
        svc.open_session("b", Graph::new(4)).unwrap();
        feed(&svc, 5, 40);
        svc.close_session("b").unwrap().expect("b was live");
        svc.query("a").unwrap().expect("a settles"); // barrier before "crash"
        std::mem::forget(svc);

        let svc = ScoringService::recover(cfg).unwrap();
        assert_eq!(svc.recovery().restored_sessions, 1);
        assert_eq!(svc.query("b").unwrap(), None, "CLOSE must replay");
        assert!(svc.query("a").unwrap().is_some());
        svc.finish();
        std::fs::remove_dir_all(root).ok();
    }

    #[test]
    fn snapshot_epoch_requires_durability() {
        let svc = ScoringService::start(ServiceConfig { shards: 1, ..Default::default() });
        assert!(svc.snapshot_epoch().is_err());
        svc.finish();
    }

    #[test]
    fn recover_rebinds_shard_count_bit_identically() {
        // a 4-shard durability directory (epoch snapshot + WAL tail) must
        // restart on 2 and on 8 shards with bit-identical session state —
        // replay routes every session through shard_of with the new count
        let mut want: Option<Vec<SessionSnapshot>> = None;
        for &new_shards in &[4usize, 2, 8] {
            let (mut cfg, root) = durable_cfg(&format!("rebind{new_shards}"));
            cfg.shards = 4;
            let svc = ScoringService::recover(cfg.clone()).unwrap();
            svc.open_session("a", Graph::new(4)).unwrap();
            svc.open_session("b", Graph::new(4)).unwrap();
            feed(&svc, 9, 110);
            svc.snapshot_epoch().unwrap();
            feed(&svc, 4, 70); // WAL tail past the epoch
            let live =
                vec![svc.query("a").unwrap().unwrap(), svc.query("b").unwrap().unwrap()];
            match &want {
                None => want = Some(live),
                Some(w) => {
                    for (l, r) in live.iter().zip(w) {
                        assert_snapshots_bit_identical(l, r);
                    }
                }
            }
            std::mem::forget(svc); // crash: only snapshot + WAL survive

            cfg.shards = new_shards;
            let svc = ScoringService::recover(cfg).unwrap();
            assert_eq!(svc.shards(), new_shards);
            assert_eq!(svc.recovery().restored_sessions, 2);
            let want_snaps = want.as_ref().unwrap();
            for want_snap in want_snaps {
                let got = svc.query(&want_snap.id).unwrap().unwrap();
                assert_snapshots_bit_identical(&got, want_snap);
            }
            // the rebind committed a fresh epoch: a second restart on the
            // same (new) count must see only new-layout state and agree
            svc.finish();
            if new_shards != 4 {
                let mut dur = DurabilityConfig::new(&root);
                dur.fsync = FsyncPolicy::Always;
                let cfg2 = ServiceConfig {
                    shards: new_shards,
                    durability: Some(dur),
                    ..Default::default()
                };
                let svc = ScoringService::recover(cfg2).unwrap();
                for want_snap in want_snaps {
                    let got = svc.query(&want_snap.id).unwrap().unwrap();
                    assert_snapshots_bit_identical(&got, want_snap);
                }
                svc.finish();
            }
            std::fs::remove_dir_all(root).ok();
        }
    }

    #[test]
    fn reliable_seq_tracks_acks_dups_and_gaps() {
        let svc = ScoringService::start(ServiceConfig { shards: 1, ..Default::default() });
        // no reliable session yet: everything is a gap at acked=0
        assert_eq!(svc.reliable_seq("a", 1), SeqOutcome::Gap { acked: 0 });
        let epoch = svc.reliable_begin("a");
        assert!(epoch > 0);
        assert_eq!(svc.reliable_resume("a", epoch), Some((epoch, 0)));
        assert_eq!(svc.reliable_resume("a", epoch + 1), None, "epoch mismatch");
        assert_eq!(svc.reliable_resume("a", 0), None, "0 always opens fresh");
        assert_eq!(svc.reliable_seq("a", 1), SeqOutcome::Apply);
        svc.reliable_ack("a", 1);
        assert_eq!(svc.reliable_seq("a", 1), SeqOutcome::Duplicate { acked: 1 });
        assert_eq!(svc.reliable_seq("a", 2), SeqOutcome::Apply);
        assert_eq!(svc.reliable_seq("a", 3), SeqOutcome::Gap { acked: 1 });
        svc.reliable_ack("a", 0); // acks never rewind
        assert_eq!(svc.reliable_seq("a", 2), SeqOutcome::Apply);
        // a fresh begin rotates the epoch and resets the ack line
        let epoch2 = svc.reliable_begin("a");
        assert_ne!(epoch2, epoch);
        assert_eq!(svc.reliable_resume("a", epoch), None, "old epoch is dead");
        assert_eq!(svc.reliable_seq("a", 1), SeqOutcome::Apply);
        svc.reliable_forget("a");
        assert_eq!(svc.reliable_seq("a", 1), SeqOutcome::Gap { acked: 0 });
        assert_eq!(svc.durability_status(), "off");
        svc.finish();
    }

    #[test]
    fn reopening_a_session_resets_it() {
        let svc = ScoringService::start(ServiceConfig { shards: 1, ..Default::default() });
        svc.open_session("a", Graph::new(2)).unwrap();
        svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
        svc.submit("a", StreamEvent::Tick).unwrap();
        svc.open_session("a", Graph::new(2)).unwrap(); // reset
        svc.submit("a", StreamEvent::Tick).unwrap();
        let report = svc.finish();
        let s = report.session("a").unwrap();
        assert_eq!(s.records.len(), 1, "reset session only saw the final empty window");
        assert_eq!(s.edges, 0);
    }
}
