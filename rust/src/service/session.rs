//! One tenant's scoring state: the reusable stream components (batcher,
//! scorer, anomaly detector, resync schedule) bundled behind a session id,
//! plus the per-session report extracted when the service finishes.

use super::config::ServiceConfig;
use crate::durability::wal::WalWriter;
use crate::durability::SessionDurableMeta;
use crate::entropy::FingerState;
use crate::graph::Graph;
use crate::stream::window::{AnomalyDetector, ScoreRecord, WindowBatcher, WindowScorer};
use crate::stream::{checkpoint, StreamEvent};
use std::path::{Path, PathBuf};

/// A live session inside a shard worker.
#[derive(Debug)]
pub struct SessionState {
    id: String,
    batcher: WindowBatcher,
    scorer: WindowScorer,
    records: Vec<ScoreRecord>,
    events: usize,
    /// Anomalous windows scored before the epoch this session was restored
    /// from (those windows' records live only in the crashed process).
    base_anomalies: usize,
    /// Last (jsdist, anomalous) carried over from the restore manifest, used
    /// until this process scores a window of its own.
    restored_last: Option<(f64, bool)>,
}

impl SessionState {
    /// Fresh session starting from `initial` under the service's policy.
    pub fn new(id: impl Into<String>, initial: Graph, cfg: &ServiceConfig) -> Self {
        Self::from_finger_state(id, FingerState::with_policy(initial, cfg.policy), cfg)
    }

    /// Session resuming from an existing state (checkpoint restore).
    pub fn from_finger_state(
        id: impl Into<String>,
        state: FingerState,
        cfg: &ServiceConfig,
    ) -> Self {
        Self {
            id: id.into(),
            batcher: WindowBatcher::new(),
            scorer: WindowScorer::new(
                state,
                AnomalyDetector::new(cfg.anomaly_sigma, cfg.anomaly_window),
                cfg.resync.clone(),
            ),
            records: Vec::new(),
            events: 0,
            base_anomalies: 0,
            restored_last: None,
        }
    }

    /// Session resuming at an epoch cut: the checkpointed (canonical)
    /// `FingerState` plus the manifest's durable metadata — scorer progress,
    /// the adaptive resync schedule's live position, and detector history
    /// restored *verbatim*, so the resumed session's future behavior is
    /// bit-identical to the crashed one's.
    pub fn from_durable(
        state: FingerState,
        meta: &SessionDurableMeta,
        cfg: &ServiceConfig,
    ) -> Self {
        let mut s = Self::from_finger_state(meta.id.clone(), state, cfg);
        s.scorer.restore_progress(
            meta.windows as usize,
            meta.interval,
            meta.since_resync,
            meta.resyncs,
            meta.max_drift,
        );
        s.scorer.restore_detector(&meta.trailing, meta.observed);
        s.events = meta.events;
        s.base_anomalies = meta.anomalies;
        s.restored_last = meta.last;
        s
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    /// Feed one event; scores a window when `ev` closes one. Allocation-free
    /// in steady state: the batcher lends the coalesced window out of its
    /// reusable buffer and the scorer reuses its own scratch workspace.
    /// Returns `true` when this event closed (and scored) a window, so the
    /// shard worker can attribute scored windows to its shard in the metrics
    /// registry without re-deriving window boundaries.
    pub fn on_event(&mut self, ev: StreamEvent) -> bool {
        self.on_event_durable(ev, None)
    }

    /// [`SessionState::on_event`] with write-ahead logging: when `ev` closes
    /// a window and a WAL is live, the coalesced delta is appended (and
    /// fsynced per policy) *before* the window is scored. Still
    /// allocation-free in steady state — the WAL writer encodes into its own
    /// reusable buffer.
    pub fn on_event_durable(&mut self, ev: StreamEvent, wal: Option<&mut WalWriter>) -> bool {
        self.events += 1;
        if let Some((delta, n_events)) = self.batcher.push_ref(ev) {
            if let Some(w) = wal {
                w.append_window(&self.id, self.scorer.windows() as u64, n_events, delta);
            }
            let record = self.scorer.score(delta, n_events);
            self.records.push(record);
            return true;
        }
        false
    }

    /// Score any trailing partial window (stream ended without a tick).
    /// Returns `true` when there was one to score.
    pub fn flush(&mut self) -> bool {
        self.flush_durable(None)
    }

    /// [`SessionState::flush`] with write-ahead logging (drain path).
    pub fn flush_durable(&mut self, wal: Option<&mut WalWriter>) -> bool {
        if let Some((delta, n_events)) = self.batcher.flush_ref() {
            if let Some(w) = wal {
                w.append_window(&self.id, self.scorer.windows() as u64, n_events, delta);
            }
            let record = self.scorer.score(delta, n_events);
            self.records.push(record);
            return true;
        }
        false
    }

    /// Replay one WAL window record through the normal scoring path.
    /// Records whose sequence number precedes the scorer's position are
    /// already covered by the restored snapshot and skipped (the WAL epoch
    /// segment can overlap the snapshot by design). Returns `true` when the
    /// window was scored.
    pub fn replay_window(&mut self, window_seq: u64, n_events: usize, delta: &crate::graph::DeltaGraph) -> bool {
        if window_seq < self.scorer.windows() as u64 {
            return false;
        }
        self.events += n_events;
        let record = self.scorer.score(delta, n_events);
        self.records.push(record);
        true
    }

    /// Canonicalize the live state at an epoch barrier: replace the
    /// incremental `FingerState` with its checkpoint-format roundtrip (the
    /// exact state a future recovery will rebuild from this epoch's files)
    /// and re-derive the detector's rolling sums. Idempotent — the
    /// roundtrip is a projection — so replaying an EPOCH marker over an
    /// already-canonical state is a no-op. Returns `false` only if the
    /// in-memory serialization failed (the live state is left untouched).
    pub fn canonicalize(&mut self) -> bool {
        let mut buf = Vec::new();
        if checkpoint::write_state(&mut buf, self.scorer.state()).is_err() {
            return false;
        }
        match checkpoint::read_state(std::io::Cursor::new(&buf), self.scorer.state().policy()) {
            Ok(state) => {
                self.scorer.replace_state(state);
                self.scorer.canonicalize_detector();
                true
            }
            Err(_) => false,
        }
    }

    /// The durable metadata an epoch manifest records for this session.
    /// `events` excludes the open window's pending events — partial windows
    /// are not durable (they are in neither the WAL nor the snapshot), so
    /// the durable count must not include them either.
    pub fn durable_meta(&self, shard: usize) -> SessionDurableMeta {
        let last = self.records.last().map(|r| (r.jsdist, r.anomalous)).or(self.restored_last);
        SessionDurableMeta {
            id: self.id.clone(),
            shard,
            windows: self.scorer.windows() as u64,
            events: self.events - self.batcher.pending_events(),
            anomalies: self.base_anomalies
                + self.records.iter().filter(|r| r.anomalous).count(),
            interval: self.scorer.resync_interval(),
            since_resync: self.scorer.since_resync(),
            resyncs: self.scorer.resyncs(),
            max_drift: self.scorer.max_drift(),
            last,
            observed: self.scorer.detector().observed(),
            trailing: self.scorer.detector().trailing_scores().collect(),
        }
    }

    pub fn state(&self) -> &FingerState {
        self.scorer.state()
    }

    pub fn records(&self) -> &[ScoreRecord] {
        &self.records
    }

    /// Events routed to this session so far (including ticks).
    pub fn events(&self) -> usize {
        self.events
    }

    /// Point-in-time view of the live session (served by
    /// [`crate::service::ScoringService::query`] and the net front end's
    /// `QUERY` verb). Cheap: no scoring work, no graph copies.
    pub fn snapshot(&self) -> SessionSnapshot {
        let last = self.records.last().map(|r| (r.jsdist, r.anomalous)).or(self.restored_last);
        SessionSnapshot {
            id: self.id.clone(),
            windows: self.scorer.windows(),
            events: self.events,
            last_jsdist: last.map(|(js, _)| js),
            last_anomalous: last.map(|(_, a)| a).unwrap_or(false),
            htilde: self.scorer.state().htilde(),
            nodes: self.scorer.state().graph().num_nodes(),
            edges: self.scorer.state().graph().num_edges(),
            anomalies: self.base_anomalies
                + self.records.iter().filter(|r| r.anomalous).count(),
            pending_events: self.batcher.pending_events(),
        }
    }

    /// Snapshot this session's state to `dir/<encoded-id>.ckpt`.
    pub fn checkpoint_into(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.ckpt", encode_session_id(&self.id)));
        checkpoint::save(self.state(), &path)?;
        Ok(path)
    }

    /// Finalize into a report (flushes any open window first).
    pub fn into_report(mut self) -> SessionReport {
        self.flush();
        let anomalies =
            self.records.iter().filter(|r| r.anomalous).map(|r| r.window).collect();
        SessionReport {
            htilde: self.scorer.state().htilde(),
            nodes: self.scorer.state().graph().num_nodes(),
            edges: self.scorer.state().graph().num_edges(),
            resyncs: self.scorer.resyncs(),
            max_resync_drift: self.scorer.max_drift(),
            anomalies,
            id: self.id,
            records: self.records,
            events: self.events,
        }
    }
}

/// Filesystem-safe checkpoint stem. The encoding is injective (distinct ids
/// never collide on disk) and reversible, so ids round-trip exactly through
/// `restore_sessions`: bytes outside `[A-Za-z0-9._-]` — and `%` itself —
/// become `%XX` hex escapes.
pub fn encode_session_id(id: &str) -> String {
    let mut out = String::with_capacity(id.len());
    for &b in id.as_bytes() {
        let c = b as char;
        if b.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
            out.push(c);
        } else {
            out.push_str(&format!("%{b:02X}"));
        }
    }
    out
}

/// Inverse of [`encode_session_id`]; `None` on malformed escapes (a file not
/// written by this encoder).
pub fn decode_session_id(stem: &str) -> Option<String> {
    let bytes = stem.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut k = 0;
    while k < bytes.len() {
        // finger-lint: allow(FL001): k < bytes.len() loop bound
        if bytes[k] == b'%' {
            let hex = bytes.get(k + 1..k + 3)?;
            // finger-lint: allow(FL001): hex is a length-checked 2-byte slice
            let hi = (hex[0] as char).to_digit(16)?;
            // finger-lint: allow(FL001): hex is a length-checked 2-byte slice
            let lo = (hex[1] as char).to_digit(16)?;
            out.push((hi * 16 + lo) as u8);
            k += 3;
        } else {
            // finger-lint: allow(FL001): k < bytes.len() loop bound
            out.push(bytes[k]);
            k += 1;
        }
    }
    String::from_utf8(out).ok()
}

/// Point-in-time stats of a live session, readable while the service runs
/// (unlike [`SessionReport`], which is extracted at `finish`).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    pub id: String,
    /// Windows scored so far.
    pub windows: usize,
    /// Events routed to this session so far (including ticks).
    pub events: usize,
    /// JSdist of the most recently scored window (`None` before any tick).
    pub last_jsdist: Option<f64>,
    /// Whether that window was flagged anomalous.
    pub last_anomalous: bool,
    /// H̃ of the session's current graph.
    pub htilde: f64,
    pub nodes: usize,
    pub edges: usize,
    /// Windows flagged anomalous so far.
    pub anomalies: usize,
    /// Events accumulated in the currently-open (not yet scored) window.
    pub pending_events: usize,
}

/// Everything the service knows about one session at finish time.
#[derive(Debug, Clone)]
pub struct SessionReport {
    pub id: String,
    pub records: Vec<ScoreRecord>,
    /// Events routed to this session (including ticks).
    pub events: usize,
    /// H̃ of the session's final graph.
    pub htilde: f64,
    pub nodes: usize,
    pub edges: usize,
    /// Window indices flagged anomalous.
    pub anomalies: Vec<usize>,
    /// Drift-bounded resyncs performed over the session's lifetime.
    pub resyncs: u64,
    /// Largest |ΔQ| correction any resync applied.
    pub max_resync_drift: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::jsdist_incremental;
    use crate::graph::DeltaGraph;
    use crate::util::Pcg64;

    fn cfg() -> ServiceConfig {
        ServiceConfig::default()
    }

    #[test]
    fn session_scores_windows_like_direct_loop() {
        let mut rng = Pcg64::new(41);
        let g = crate::generators::erdos_renyi(30, 0.1, &mut rng);
        let mut deltas = Vec::new();
        for _ in 0..6 {
            let mut d = DeltaGraph::new();
            for _ in 0..4 {
                let i = rng.below(30) as u32;
                let j = (i + 1 + rng.below(29) as u32) % 30;
                if i != j {
                    d.add(i, j, rng.uniform(0.1, 1.0));
                }
            }
            deltas.push(d.coalesced());
        }
        let mut session = SessionState::new("s", g.clone(), &cfg());
        for ev in crate::stream::event::events_from_deltas(&deltas) {
            session.on_event(ev);
        }
        let mut state = FingerState::new(g);
        for (t, d) in deltas.iter().enumerate() {
            let js = jsdist_incremental(&mut state, d);
            assert!(
                (session.records()[t].jsdist - js).abs() < 1e-12,
                "window {t}: {} vs {js}",
                session.records()[t].jsdist
            );
        }
        let report = session.into_report();
        assert_eq!(report.records.len(), 6);
        assert!((report.htilde - state.htilde()).abs() < 1e-12);
    }

    #[test]
    fn trailing_partial_window_flushed_in_report() {
        let mut session = SessionState::new("s", Graph::new(4), &cfg());
        session.on_event(StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 });
        session.on_event(StreamEvent::Tick);
        session.on_event(StreamEvent::EdgeDelta { i: 1, j: 2, dw: 1.0 }); // no tick
        let report = session.into_report();
        assert_eq!(report.records.len(), 2);
        assert_eq!(report.events, 3);
        assert_eq!(report.edges, 2);
    }

    #[test]
    fn checkpoint_roundtrip_preserves_htilde() {
        let mut session = SessionState::new("tenant-1", Graph::new(6), &cfg());
        for k in 0..5u32 {
            session.on_event(StreamEvent::EdgeDelta { i: k, j: k + 1, dw: 1.0 + k as f64 });
        }
        session.on_event(StreamEvent::Tick);
        let dir = std::env::temp_dir().join("finger_session_ckpt");
        let path = session.checkpoint_into(&dir).unwrap();
        let restored = checkpoint::load(&path).unwrap();
        assert!((restored.htilde() - session.state().htilde()).abs() < 1e-12);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn id_encoding_is_path_safe_injective_and_reversible() {
        assert_eq!(encode_session_id("plain-id_1.2"), "plain-id_1.2");
        assert_eq!(encode_session_id("user/42:a"), "user%2F42%3Aa");
        // distinct ids that a lossy sanitizer would collapse stay distinct
        assert_ne!(encode_session_id("a/b"), encode_session_id("a_b"));
        for id in ["a/b", "a_b", "100% métrics", "s%2F", "plain"] {
            assert_eq!(decode_session_id(&encode_session_id(id)).as_deref(), Some(id));
        }
        assert_eq!(decode_session_id("bad%zz"), None);
    }
}
