//! Service configuration, constructible programmatically or from the
//! `[service]` section of a config file (`cli::Config`).

use crate::cli::Config;
use crate::durability::{DurabilityConfig, FsyncPolicy, OnError};
use crate::entropy::SmaxPolicy;
use crate::stream::ResyncPolicy;
use std::path::PathBuf;

/// Knobs for the sharded scoring engine.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Shard worker count (sessions are hash-partitioned across these).
    pub shards: usize,
    /// Bounded queue depth per shard (backpressure knob: `submit` blocks
    /// when the target shard's queue is full).
    pub channel_capacity: usize,
    /// Online anomaly threshold: score > μ + k·σ over the trailing window.
    pub anomaly_sigma: f64,
    /// Trailing window length for the running anomaly statistics.
    pub anomaly_window: usize,
    /// s_max maintenance policy for every session's `FingerState`.
    pub policy: SmaxPolicy,
    /// Drift-bounded auto-resync schedule for long-lived sessions.
    pub resync: ResyncPolicy,
    /// Create a session (empty initial graph) on first event for an unknown
    /// id; when false such events are dropped and counted.
    pub auto_create_sessions: bool,
    /// Snapshot every session here on `finish` (one `<id>.ckpt` per session).
    pub checkpoint_dir: Option<PathBuf>,
    /// Per-shard write-ahead logging + epoch snapshots (`docs/DURABILITY.md`).
    /// `Some` turns the durability subsystem on: shard workers write-ahead
    /// every committed window, `EPOCH` barriers cut online snapshots, and
    /// startup recovers snapshot + WAL tail into bit-identical sessions.
    pub durability: Option<DurabilityConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            channel_capacity: 256,
            anomaly_sigma: 3.0,
            anomaly_window: 24,
            policy: SmaxPolicy::default(),
            resync: ResyncPolicy::default(),
            auto_create_sessions: true,
            checkpoint_dir: None,
            durability: None,
        }
    }
}

impl ServiceConfig {
    /// Read the `[service]` and `[durability]` sections of a parsed config
    /// file; missing keys fall back to the defaults above. Recognized
    /// `[service]` keys: `shards`, `channel_capacity`, `anomaly_sigma`,
    /// `anomaly_window`, `smax_policy` (`exact` | `paper`),
    /// `resync_interval` (windows, 0 disables), `auto_create_sessions`,
    /// `checkpoint_dir`. Recognized `[durability]` keys (presence of `dir`
    /// turns durability on): `dir`, `fsync`
    /// (`always` | `every_ms[=N]` | `every_n[=N]`; an unparseable spec falls
    /// back to the default), `fsync_ms`, `fsync_windows` (numeric overrides,
    /// taking precedence over `fsync`), `segment_bytes`,
    /// `snapshot_interval_ms` (0 disables the periodic snapshot timer),
    /// `on_error` (`fail_stop` | `degrade` — what WAL IO failure does to the
    /// service; an unparseable spec falls back to `fail_stop`).
    pub fn from_config(c: &Config) -> Self {
        let d = Self::default();
        Self {
            shards: c.get_or("service.shards", d.shards).max(1),
            channel_capacity: c.get_or("service.channel_capacity", d.channel_capacity).max(1),
            anomaly_sigma: c.get_or("service.anomaly_sigma", d.anomaly_sigma),
            anomaly_window: c.get_or("service.anomaly_window", d.anomaly_window).max(1),
            policy: match c.get("service.smax_policy") {
                Some("paper") | Some("paper-faithful") => SmaxPolicy::PaperFaithful,
                _ => SmaxPolicy::Exact,
            },
            resync: ResyncPolicy::every(
                c.get_or("service.resync_interval", d.resync.initial_interval),
            ),
            auto_create_sessions: c
                .get_bool("service.auto_create_sessions", d.auto_create_sessions),
            checkpoint_dir: c.get("service.checkpoint_dir").map(PathBuf::from),
            durability: c.get("durability.dir").map(|dir| {
                let mut dur = DurabilityConfig::new(dir);
                if let Some(p) = c.get("durability.fsync").and_then(FsyncPolicy::parse) {
                    dur.fsync = p;
                }
                if let Some(ms) = c.get("durability.fsync_ms").and_then(|v| v.parse().ok()) {
                    dur.fsync = FsyncPolicy::EveryMs(ms);
                }
                if let Some(n) =
                    c.get("durability.fsync_windows").and_then(|v| v.parse::<u64>().ok())
                {
                    dur.fsync = FsyncPolicy::EveryNWindows(n.max(1));
                }
                dur.segment_bytes = c.get_or("durability.segment_bytes", dur.segment_bytes);
                dur.snapshot_interval_ms =
                    c.get_or("durability.snapshot_interval_ms", dur.snapshot_interval_ms);
                if let Some(p) = c.get("durability.on_error").and_then(OnError::parse) {
                    dur.on_error = p;
                }
                dur
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_reads_service_section() {
        let c = Config::parse(
            "[service]\nshards = 8\nchannel_capacity = 2\nsmax_policy = \"paper\"\n\
             resync_interval = 0\nauto_create_sessions = false\ncheckpoint_dir = \"/tmp/x\"\n",
        )
        .unwrap();
        let s = ServiceConfig::from_config(&c);
        assert_eq!(s.shards, 8);
        assert_eq!(s.channel_capacity, 2);
        assert_eq!(s.policy, SmaxPolicy::PaperFaithful);
        assert_eq!(s.resync.initial_interval, 0);
        assert!(!s.auto_create_sessions);
        assert_eq!(s.checkpoint_dir.as_deref(), Some(std::path::Path::new("/tmp/x")));
    }

    #[test]
    fn from_config_defaults_on_empty() {
        let s = ServiceConfig::from_config(&Config::parse("").unwrap());
        let d = ServiceConfig::default();
        assert_eq!(s.shards, d.shards);
        assert_eq!(s.policy, SmaxPolicy::Exact);
        assert!(s.checkpoint_dir.is_none());
        assert!(s.durability.is_none());
    }

    #[test]
    fn from_config_reads_durability_section() {
        let c = Config::parse(
            "[durability]\ndir = \"/tmp/dur\"\nfsync = \"every_n=8\"\n\
             segment_bytes = 4096\nsnapshot_interval_ms = 500\n",
        )
        .unwrap();
        let dur = ServiceConfig::from_config(&c).durability.expect("dir enables durability");
        assert_eq!(dur.dir, std::path::PathBuf::from("/tmp/dur"));
        assert_eq!(dur.fsync, FsyncPolicy::EveryNWindows(8));
        assert_eq!(dur.segment_bytes, 4096);
        assert_eq!(dur.snapshot_interval_ms, 500);
        assert_eq!(dur.on_error, OnError::FailStop, "fail_stop is the default");

        let c = Config::parse("[durability]\ndir = \"/d\"\non_error = \"degrade\"\n").unwrap();
        let dur = ServiceConfig::from_config(&c).durability.unwrap();
        assert_eq!(dur.on_error, OnError::Degrade);

        // numeric overrides beat the spec string; bad specs fall back
        let c = Config::parse("[durability]\ndir = \"/d\"\nfsync = \"bogus\"\nfsync_ms = 7\n")
            .unwrap();
        let dur = ServiceConfig::from_config(&c).durability.unwrap();
        assert_eq!(dur.fsync, FsyncPolicy::EveryMs(7));

        // no dir, no durability — other keys alone don't enable it
        let c = Config::parse("[durability]\nfsync = \"always\"\n").unwrap();
        assert!(ServiceConfig::from_config(&c).durability.is_none());
    }
}
