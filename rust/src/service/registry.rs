//! Session bookkeeping: the deterministic session→shard hash and the
//! per-shard registry of live sessions (each shard worker owns one
//! `SessionRegistry` outright — no locks on the scoring path).

use super::session::SessionState;
use crate::util::hash::{DetHashMap, FxHasher};
use std::hash::{Hash, Hasher};

/// Deterministic shard assignment for a session id: FxHash of the id bytes
/// modulo the shard count. Stable across runs, platforms and submission
/// orders, so tests (and operators) can predict event routing.
pub fn shard_of(session_id: &str, shards: usize) -> usize {
    let mut h = FxHasher::default();
    session_id.hash(&mut h);
    (h.finish() % shards.max(1) as u64) as usize
}

/// The sessions owned by one shard worker, keyed by session id.
#[derive(Debug, Default)]
pub struct SessionRegistry {
    sessions: DetHashMap<String, SessionState>,
}

impl SessionRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.sessions.contains_key(id)
    }

    /// Register a session, replacing any previous one under the same id.
    pub fn insert(&mut self, session: SessionState) {
        self.sessions.insert(session.id().to_string(), session);
    }

    pub fn get(&self, id: &str) -> Option<&SessionState> {
        self.sessions.get(id)
    }

    pub fn get_mut(&mut self, id: &str) -> Option<&mut SessionState> {
        self.sessions.get_mut(id)
    }

    /// Retire one session, handing its state back to the caller (the
    /// `CLOSE` path). `None` when no such session is live on this shard.
    pub fn remove(&mut self, id: &str) -> Option<SessionState> {
        self.sessions.remove(id)
    }

    /// Mutable walk over every live session (the epoch barrier
    /// canonicalizes and checkpoints each in place).
    pub fn sessions_mut(&mut self) -> impl Iterator<Item = &mut SessionState> {
        self.sessions.values_mut()
    }

    /// Drain all sessions (finish path).
    pub fn into_sessions(self) -> impl Iterator<Item = SessionState> {
        self.sessions.into_values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_assignment_is_deterministic_and_in_range() {
        for shards in [1usize, 2, 3, 8, 64] {
            for id in ["alice", "bob", "session-12345", ""] {
                let s = shard_of(id, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(id, shards), "same id must re-hash identically");
            }
        }
    }

    #[test]
    fn shard_assignment_spreads_sessions() {
        let shards = 8;
        let mut seen = vec![false; shards];
        for k in 0..256 {
            seen[shard_of(&format!("session-{k}"), shards)] = true;
        }
        assert!(seen.iter().all(|&b| b), "256 sessions must cover all 8 shards");
    }
}
