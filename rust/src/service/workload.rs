//! Deterministic synthetic multi-tenant workloads plus a driver that pushes
//! them through a `ScoringService` from concurrent producer threads. Shared
//! by `finger serve-bench`, `finger load`, `benches/service_throughput.rs`,
//! `examples/multi_tenant.rs` and the service/net integration tests.
//!
//! Besides the uniform Erdős–Rényi churn tenants, a workload can mix in
//! *dataset-preset* tenants backed by the paper's application generators
//! (`crate::datasets`): evolving wiki hyperlink streams (Table 2), DoS-
//! attacked AS-router snapshots (Table 3), and Hi-C contact-map sequences
//! (Fig 4) — so a multi-tenant run exercises the service with the same
//! traffic shapes the paper evaluates.

use super::config::ServiceConfig;
use super::engine::{ScoringService, ServiceReport, SubmitError};
use crate::datasets::{dos_inject, hic_sequence, oregon_snapshots, wiki_stream};
use crate::datasets::{HicConfig, OregonConfig, WikiConfig};
use crate::graph::{DeltaGraph, Graph, GraphSequence};
use crate::stream::{event, StreamEvent};
use crate::util::Pcg64;

/// Traffic shape of one tenant in a mixed workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantPreset {
    /// Uniform Erdős–Rényi churn (the original synthetic tenant).
    Synthetic,
    /// Evolving hyperlink network with bursty edit storms (Table 2 analog).
    Wiki,
    /// AS-router snapshots with an injected star-burst DoS (Table 3 analog).
    Dos,
    /// Genomic contact-map sequence with a bifurcation (Fig 4 analog).
    HiC,
}

impl TenantPreset {
    /// Parse a preset name (`synthetic` | `wiki` | `dos` | `hic`).
    pub fn parse(name: &str) -> Option<Self> {
        match name.trim().to_ascii_lowercase().as_str() {
            "synthetic" | "er" => Some(Self::Synthetic),
            "wiki" => Some(Self::Wiki),
            "dos" => Some(Self::Dos),
            "hic" | "hi-c" => Some(Self::HiC),
            _ => None,
        }
    }

    /// Parse a comma-separated preset list; `None` if any element is unknown.
    pub fn parse_list(raw: &str) -> Option<Vec<Self>> {
        raw.split(',').map(Self::parse).collect()
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Synthetic => "synthetic",
            Self::Wiki => "wiki",
            Self::Dos => "dos",
            Self::HiC => "hic",
        }
    }
}

/// Shape of one synthetic multi-tenant workload.
#[derive(Debug, Clone)]
pub struct TenantWorkloadConfig {
    /// Concurrent sessions (tenants).
    pub sessions: usize,
    /// Tick-separated windows per session (dataset presets may emit a
    /// slightly different count, set by their own sequence lengths).
    pub windows: usize,
    /// Edge events per window (synthetic tenants; dataset presets derive
    /// their event counts from the generated deltas).
    pub events_per_window: usize,
    /// Nodes in each session's initial graph (dataset presets scale their
    /// generator dimensions from this).
    pub nodes_per_session: usize,
    /// Presets assigned to sessions round-robin; empty means all synthetic.
    pub presets: Vec<TenantPreset>,
    pub seed: u64,
}

impl Default for TenantWorkloadConfig {
    fn default() -> Self {
        Self {
            sessions: 256,
            windows: 16,
            events_per_window: 60,
            nodes_per_session: 64,
            presets: Vec::new(),
            seed: 0x5E55,
        }
    }
}

/// One tenant's prebuilt stream: `(session id, initial graph, events)`.
pub type TenantStream = (String, Graph, Vec<StreamEvent>);

/// Generate per-session event streams. Each session gets its own RNG stream
/// (`Pcg64::with_stream`) or generator seed, so the workload is reproducible
/// and independent of how sessions are later interleaved. With a non-empty
/// `presets` list, session `s` gets `presets[s % len]` and its id is
/// prefixed with the preset name (`wiki-00003`).
pub fn tenant_streams(cfg: &TenantWorkloadConfig) -> Vec<TenantStream> {
    (0..cfg.sessions)
        .map(|s| {
            let preset = cfg
                .presets
                .get(s % cfg.presets.len().max(1))
                .copied()
                .unwrap_or(TenantPreset::Synthetic);
            let (initial, events) = match preset {
                TenantPreset::Synthetic => synthetic_stream(cfg, s),
                TenantPreset::Wiki => wiki_tenant(cfg, s),
                TenantPreset::Dos => dos_tenant(cfg, s),
                TenantPreset::HiC => hic_tenant(cfg, s),
            };
            let id = if cfg.presets.is_empty() {
                format!("session-{s:05}")
            } else {
                format!("{}-{s:05}", preset.name())
            };
            (id, initial, events)
        })
        .collect()
}

fn synthetic_stream(cfg: &TenantWorkloadConfig, s: usize) -> (Graph, Vec<StreamEvent>) {
    let n = cfg.nodes_per_session.max(2);
    let mut rng = Pcg64::with_stream(cfg.seed, s as u64);
    let initial = crate::generators::erdos_renyi_avg_degree(n, 6.0, &mut rng);
    let mut events = Vec::with_capacity(cfg.windows * (cfg.events_per_window + 1));
    for _ in 0..cfg.windows {
        for _ in 0..cfg.events_per_window {
            let i = rng.below(n) as u32;
            let j = (i + 1 + rng.below(n - 1) as u32) % n as u32;
            let dw = if rng.bernoulli(0.25) {
                -rng.uniform(0.1, 1.0) // weaken/delete
            } else {
                rng.uniform(0.1, 1.0)
            };
            events.push(StreamEvent::EdgeDelta { i, j, dw });
        }
        events.push(StreamEvent::Tick);
    }
    (initial, events)
}

/// Per-tenant generator seed: decorrelates tenants sharing a preset.
fn tenant_seed(cfg: &TenantWorkloadConfig, s: usize) -> u64 {
    cfg.seed.wrapping_add((s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

fn wiki_tenant(cfg: &TenantWorkloadConfig, s: usize) -> (Graph, Vec<StreamEvent>) {
    let n = cfg.nodes_per_session.max(24);
    let ws = wiki_stream(&WikiConfig {
        months: cfg.windows.max(2),
        initial_nodes: n,
        growth_per_month: (n / 8).max(2),
        attach: 3,
        churn_frac: 0.02,
        burst_months: (cfg.windows / 6).min(3),
        burst_factor: 6.0,
        seed: tenant_seed(cfg, s),
    });
    (ws.initial, event::events_from_deltas(&ws.deltas))
}

fn dos_tenant(cfg: &TenantWorkloadConfig, s: usize) -> (Graph, Vec<StreamEvent>) {
    let seed = tenant_seed(cfg, s);
    let snaps = oregon_snapshots(&OregonConfig {
        nodes: cfg.nodes_per_session.max(64),
        snapshots: cfg.windows.max(2) + 1,
        attach: 2,
        drift: 0.02,
        seed,
    });
    // star-burst DoS spliced into one snapshot: 5% of all nodes hit a target
    let attacked = dos_inject(&snaps, 0.05, &mut Pcg64::with_stream(seed, 1));
    sequence_stream(&attacked.seq)
}

fn hic_tenant(cfg: &TenantWorkloadConfig, s: usize) -> (Graph, Vec<StreamEvent>) {
    let samples = cfg.windows.max(2) + 1;
    let dim = cfg.nodes_per_session.clamp(24, 480);
    let seq = hic_sequence(&HicConfig {
        dim,
        samples,
        bifurcation: (samples / 2).max(1),
        band: (dim / 10).max(4),
        support_dip: (samples * 2 / 3).max(1),
        hub_dip: (samples / 4).max(1),
        seed: tenant_seed(cfg, s),
    });
    sequence_stream(&seq)
}

/// Turn a snapshot sequence into `(initial, tick-separated delta events)`.
fn sequence_stream(seq: &GraphSequence) -> (Graph, Vec<StreamEvent>) {
    let deltas: Vec<DeltaGraph> =
        seq.pairs().map(|(a, b)| DeltaGraph::diff(a, b)).collect();
    (seq.get(0).clone(), event::events_from_deltas(&deltas))
}

/// Total event count of a prebuilt workload.
pub fn workload_events(workload: &[TenantStream]) -> usize {
    workload.iter().map(|(_, _, evs)| evs.len()).sum()
}

/// Drive a prebuilt workload through a fresh service: open every session,
/// submit from `producers` threads (sessions round-robin-partitioned across
/// producers; each producer interleaves its sessions window by window so all
/// shards stay busy), then `finish`. When `batched`, each tick-delimited
/// window goes through `submit_batch` as one message; otherwise events are
/// submitted one by one. A producer failure (a shard worker died) drains
/// the service and surfaces as an error instead of aborting the process.
pub fn drive(
    cfg: &ServiceConfig,
    workload: &[TenantStream],
    producers: usize,
    batched: bool,
) -> anyhow::Result<ServiceReport> {
    let service = ScoringService::start(cfg.clone());
    for (id, initial, _) in workload {
        service
            .open_session(id, initial.clone())
            .map_err(|e| anyhow::anyhow!("open session {id}: {e}"))?;
    }
    let producers = producers.clamp(1, workload.len().max(1));
    let failure: Option<String> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(producers);
        for p in 0..producers {
            let service = &service;
            let chunk: Vec<&TenantStream> =
                workload.iter().skip(p).step_by(producers).collect();
            handles.push(scope.spawn(move || -> Result<(), SubmitError> {
                if batched {
                    // window-major round-robin of per-window batches
                    let windows: Vec<Vec<&[StreamEvent]>> = chunk
                        .iter()
                        .map(|(_, _, evs)| {
                            evs.split_inclusive(|e| matches!(e, StreamEvent::Tick))
                                .collect()
                        })
                        .collect();
                    let max_windows =
                        windows.iter().map(|w| w.len()).max().unwrap_or(0);
                    for w in 0..max_windows {
                        for (k, (id, _, _)) in chunk.iter().enumerate() {
                            if let Some(win) =
                                windows.get(k).and_then(|ws| ws.get(w))
                            {
                                service.submit_batch(id, win.to_vec())?;
                            }
                        }
                    }
                } else {
                    // event-major round-robin keeps every session live
                    let max_events =
                        chunk.iter().map(|(_, _, evs)| evs.len()).max().unwrap_or(0);
                    for t in 0..max_events {
                        for (id, _, evs) in &chunk {
                            if let Some(ev) = evs.get(t) {
                                service.submit(id, ev.clone())?;
                            }
                        }
                    }
                }
                Ok(())
            }));
        }
        let mut first = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first.get_or_insert_with(|| e.to_string());
                }
                Err(_) => {
                    first.get_or_insert_with(|| "producer thread panicked".to_string());
                }
            }
        }
        first
    });
    if let Some(msg) = failure {
        drop(service); // senders close; surviving workers exit cleanly
        anyhow::bail!("workload producer: {msg}");
    }
    Ok(service.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic() {
        let cfg = TenantWorkloadConfig { sessions: 3, windows: 2, ..Default::default() };
        let a = tenant_streams(&cfg);
        let b = tenant_streams(&cfg);
        assert_eq!(a.len(), 3);
        for ((ia, ga, ea), (ib, gb, eb)) in a.iter().zip(&b) {
            assert_eq!(ia, ib);
            assert_eq!(ga.num_edges(), gb.num_edges());
            assert_eq!(ea, eb);
        }
    }

    #[test]
    fn preset_mix_builds_wire_safe_streams() {
        let cfg = TenantWorkloadConfig {
            sessions: 4,
            windows: 4,
            events_per_window: 8,
            nodes_per_session: 32,
            presets: vec![
                TenantPreset::Synthetic,
                TenantPreset::Wiki,
                TenantPreset::Dos,
                TenantPreset::HiC,
            ],
            seed: 77,
        };
        let streams = tenant_streams(&cfg);
        assert_eq!(streams.len(), 4);
        for (k, name) in ["synthetic", "wiki", "dos", "hic"].iter().enumerate() {
            let (id, initial, events) = &streams[k];
            assert!(id.starts_with(name), "{id} should carry its preset name");
            assert!(initial.num_nodes() > 0);
            assert!(events.iter().filter(|e| matches!(e, StreamEvent::Tick)).count() >= 2);
            // every event must survive the hardened wire parse round-trip
            // (the net front end serializes exactly these lines)
            for ev in events {
                assert_eq!(
                    StreamEvent::parse(&ev.to_line()).as_ref(),
                    Some(ev),
                    "{name} emitted a wire-unsafe event: {ev:?}"
                );
            }
        }
        // determinism: same config → identical streams
        let again = tenant_streams(&cfg);
        for ((ia, _, ea), (ib, _, eb)) in streams.iter().zip(&again) {
            assert_eq!(ia, ib);
            assert_eq!(ea, eb);
        }
    }

    #[test]
    fn preset_parse_list() {
        assert_eq!(
            TenantPreset::parse_list("wiki, dos,hic,synthetic"),
            Some(vec![
                TenantPreset::Wiki,
                TenantPreset::Dos,
                TenantPreset::HiC,
                TenantPreset::Synthetic,
            ])
        );
        assert_eq!(TenantPreset::parse_list("wiki,unknown"), None);
    }

    #[test]
    fn batched_and_unbatched_drives_agree() {
        let wl_cfg = TenantWorkloadConfig {
            sessions: 6,
            windows: 3,
            events_per_window: 10,
            nodes_per_session: 16,
            seed: 9,
            ..Default::default()
        };
        let workload = tenant_streams(&wl_cfg);
        let svc_cfg = ServiceConfig { shards: 2, ..Default::default() };
        let a = drive(&svc_cfg, &workload, 2, false).unwrap();
        let b = drive(&svc_cfg, &workload, 3, true).unwrap();
        assert_eq!(a.total_events, workload_events(&workload));
        assert_eq!(a.total_events, b.total_events);
        for (ra, rb) in a.sessions.iter().zip(&b.sessions) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.records.len(), rb.records.len());
            for (x, y) in ra.records.iter().zip(&rb.records) {
                assert!((x.jsdist - y.jsdist).abs() < 1e-12, "{}", ra.id);
            }
        }
    }
}
