//! Deterministic synthetic multi-tenant workloads plus a driver that pushes
//! them through a `ScoringService` from concurrent producer threads. Shared
//! by `finger serve-bench`, `benches/service_throughput.rs`,
//! `examples/multi_tenant.rs` and the service integration tests.

use super::config::ServiceConfig;
use super::engine::{ScoringService, ServiceReport};
use crate::graph::Graph;
use crate::stream::StreamEvent;
use crate::util::Pcg64;

/// Shape of one synthetic multi-tenant workload.
#[derive(Debug, Clone)]
pub struct TenantWorkloadConfig {
    /// Concurrent sessions (tenants).
    pub sessions: usize,
    /// Tick-separated windows per session.
    pub windows: usize,
    /// Edge events per window.
    pub events_per_window: usize,
    /// Nodes in each session's initial graph.
    pub nodes_per_session: usize,
    pub seed: u64,
}

impl Default for TenantWorkloadConfig {
    fn default() -> Self {
        Self {
            sessions: 256,
            windows: 16,
            events_per_window: 60,
            nodes_per_session: 64,
            seed: 0x5E55,
        }
    }
}

/// One tenant's prebuilt stream: `(session id, initial graph, events)`.
pub type TenantStream = (String, Graph, Vec<StreamEvent>);

/// Generate per-session event streams. Each session gets its own RNG stream
/// (`Pcg64::with_stream`), so the workload is reproducible and independent
/// of how sessions are later interleaved.
pub fn tenant_streams(cfg: &TenantWorkloadConfig) -> Vec<TenantStream> {
    let n = cfg.nodes_per_session.max(2);
    (0..cfg.sessions)
        .map(|s| {
            let mut rng = Pcg64::with_stream(cfg.seed, s as u64);
            let initial = crate::generators::erdos_renyi_avg_degree(n, 6.0, &mut rng);
            let mut events =
                Vec::with_capacity(cfg.windows * (cfg.events_per_window + 1));
            for _ in 0..cfg.windows {
                for _ in 0..cfg.events_per_window {
                    let i = rng.below(n) as u32;
                    let j = (i + 1 + rng.below(n - 1) as u32) % n as u32;
                    let dw = if rng.bernoulli(0.25) {
                        -rng.uniform(0.1, 1.0) // weaken/delete
                    } else {
                        rng.uniform(0.1, 1.0)
                    };
                    events.push(StreamEvent::EdgeDelta { i, j, dw });
                }
                events.push(StreamEvent::Tick);
            }
            (format!("session-{s:05}"), initial, events)
        })
        .collect()
}

/// Total event count of a prebuilt workload.
pub fn workload_events(workload: &[TenantStream]) -> usize {
    workload.iter().map(|(_, _, evs)| evs.len()).sum()
}

/// Drive a prebuilt workload through a fresh service: open every session,
/// submit from `producers` threads (sessions round-robin-partitioned across
/// producers; each producer interleaves its sessions window by window so all
/// shards stay busy), then `finish`. When `batched`, each tick-delimited
/// window goes through `submit_batch` as one message; otherwise events are
/// submitted one by one.
pub fn drive(
    cfg: &ServiceConfig,
    workload: &[TenantStream],
    producers: usize,
    batched: bool,
) -> ServiceReport {
    let service = ScoringService::start(cfg.clone());
    for (id, initial, _) in workload {
        service.open_session(id, initial.clone()).expect("open session");
    }
    let producers = producers.clamp(1, workload.len().max(1));
    std::thread::scope(|scope| {
        for p in 0..producers {
            let service = &service;
            let chunk: Vec<&TenantStream> =
                workload.iter().skip(p).step_by(producers).collect();
            scope.spawn(move || {
                if batched {
                    // window-major round-robin of per-window batches
                    let windows: Vec<Vec<&[StreamEvent]>> = chunk
                        .iter()
                        .map(|(_, _, evs)| {
                            evs.split_inclusive(|e| matches!(e, StreamEvent::Tick))
                                .collect()
                        })
                        .collect();
                    let max_windows =
                        windows.iter().map(|w| w.len()).max().unwrap_or(0);
                    for w in 0..max_windows {
                        for (k, (id, _, _)) in chunk.iter().enumerate() {
                            if let Some(win) = windows[k].get(w) {
                                service
                                    .submit_batch(id, win.to_vec())
                                    .expect("submit batch");
                            }
                        }
                    }
                } else {
                    // event-major round-robin keeps every session live
                    let max_events =
                        chunk.iter().map(|(_, _, evs)| evs.len()).max().unwrap_or(0);
                    for t in 0..max_events {
                        for (id, _, evs) in &chunk {
                            if let Some(ev) = evs.get(t) {
                                service.submit(id, ev.clone()).expect("submit");
                            }
                        }
                    }
                }
            });
        }
    });
    service.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic() {
        let cfg = TenantWorkloadConfig { sessions: 3, windows: 2, ..Default::default() };
        let a = tenant_streams(&cfg);
        let b = tenant_streams(&cfg);
        assert_eq!(a.len(), 3);
        for ((ia, ga, ea), (ib, gb, eb)) in a.iter().zip(&b) {
            assert_eq!(ia, ib);
            assert_eq!(ga.num_edges(), gb.num_edges());
            assert_eq!(ea, eb);
        }
    }

    #[test]
    fn batched_and_unbatched_drives_agree() {
        let wl_cfg = TenantWorkloadConfig {
            sessions: 6,
            windows: 3,
            events_per_window: 10,
            nodes_per_session: 16,
            seed: 9,
        };
        let workload = tenant_streams(&wl_cfg);
        let svc_cfg = ServiceConfig { shards: 2, ..Default::default() };
        let a = drive(&svc_cfg, &workload, 2, false);
        let b = drive(&svc_cfg, &workload, 3, true);
        assert_eq!(a.total_events, workload_events(&workload));
        assert_eq!(a.total_events, b.total_events);
        for (ra, rb) in a.sessions.iter().zip(&b.sessions) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.records.len(), rb.records.len());
            for (x, y) in ra.records.iter().zip(&rb.records) {
                assert!((x.jsdist - y.jsdist).abs() < 1e-12, "{}", ra.id);
            }
        }
    }
}
