//! Sharded multi-session scoring service — the serving backbone that turns
//! the single-stream demo pipeline into a multi-tenant engine tracking many
//! evolving graphs at once (FINGER's per-update cheapness, Theorem 2, is
//! what makes per-session incremental scoring affordable at this scale).
//!
//! # Architecture
//!
//! ```text
//!                    ┌───────────────► shard 0 worker ──► SessionRegistry {id → SessionState}
//! submit(id, event) ─┤  hash(id) % N   (bounded ch)            batcher → scorer → anomaly
//!                    ├───────────────► shard 1 worker ──► ...
//!                    └───────────────► shard N-1 worker
//! ```
//!
//! * **Sharding** — sessions are hash-partitioned by id ([`shard_of`], a
//!   deterministic FxHash), so every event of a session flows through one
//!   worker in submission order: per-session processing is sequential and
//!   deterministic while distinct sessions score in parallel across N
//!   workers. No locks are taken on the scoring path — each worker owns its
//!   shard's [`SessionRegistry`] outright.
//! * **Backpressure** — each shard worker is fed by a bounded
//!   `sync_channel` of [`ServiceConfig::channel_capacity`] messages;
//!   [`ScoringService::submit`] blocks when a shard's queue is full, so a
//!   slow shard stalls its producers instead of growing memory without
//!   bound. Events are never dropped on the submit path (only events for
//!   unknown sessions when `auto_create_sessions` is off, which are counted
//!   in [`ServiceReport::dropped_events`]).
//! * **Per-session state** — every [`SessionState`] bundles the reusable
//!   stream components: a `WindowBatcher` folding events into ΔG_t windows,
//!   a `WindowScorer` owning the incremental `FingerState` (Algorithm 2 per
//!   window), an online μ + kσ `AnomalyDetector`, and a drift-bounded
//!   `ResyncPolicy` that periodically rebuilds Q/c/s_max from the graph for
//!   long-lived sessions (interval adapts to the measured |ΔQ| drift).
//! * **Checkpoint/restore** — on [`ScoringService::finish`] every session
//!   can be snapshotted to `checkpoint_dir` via `stream::checkpoint`;
//!   [`ScoringService::restore_sessions`] re-opens them (Q/c/s_max are
//!   derived from the saved graph, so no drift survives a restore).
//!
//! # Example
//!
//! ```
//! use finger::service::{ScoringService, ServiceConfig};
//! use finger::stream::StreamEvent;
//!
//! let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
//! for session in ["alice", "bob"] {
//!     svc.open_session(session, finger::graph::Graph::new(8)).unwrap();
//!     for k in 0..4u32 {
//!         svc.submit(session, StreamEvent::EdgeDelta { i: k, j: k + 1, dw: 1.0 }).unwrap();
//!     }
//!     svc.submit(session, StreamEvent::Tick).unwrap();
//! }
//! let report = svc.finish();
//! assert_eq!(report.sessions.len(), 2);
//! assert_eq!(report.total_events, 10);
//! ```

pub mod config;
pub mod engine;
pub mod registry;
pub mod session;
pub mod workload;

pub use config::ServiceConfig;
pub use engine::{
    EpochSummary, RecoveryReport, ScoringService, SeqOutcome, ServiceReport, SubmitError,
    DUR_DEGRADED, DUR_FAILED, DUR_OK,
};
pub use registry::{shard_of, SessionRegistry};
pub use session::{
    decode_session_id, encode_session_id, SessionReport, SessionSnapshot, SessionState,
};
pub use workload::{tenant_streams, TenantPreset, TenantWorkloadConfig};
