//! Synthetic evolving hyperlink network ("Wikipedia-like").
//!
//! Reproduces the statistical features the paper's Table 2 experiment
//! exercises, without the multi-GB KONECT dumps: preferential-attachment
//! growth (heavy-tailed in/out linkage), monthly snapshots presented as a
//! delta stream (additions *and* deletions), drastic early evolution that
//! stabilizes relative to the growing bulk, and a few bursty "edit storm"
//! months that a VEO proxy flags as anomalous.

use crate::graph::{DeltaGraph, Graph};
use crate::util::Pcg64;

/// Configuration for one synthetic wiki stream.
#[derive(Debug, Clone)]
pub struct WikiConfig {
    /// Number of monthly snapshots T (the paper's datasets have 75–127).
    pub months: usize,
    /// Nodes in the initial network.
    pub initial_nodes: usize,
    /// New articles per month (attached preferentially).
    pub growth_per_month: usize,
    /// Hyperlinks added per new article.
    pub attach: usize,
    /// Baseline churn: fraction of existing edges rewired per month.
    pub churn_frac: f64,
    /// Number of bursty months (edit storms) scattered over the horizon.
    pub burst_months: usize,
    /// Burst multiplier on churn and growth.
    pub burst_factor: f64,
    pub seed: u64,
}

impl Default for WikiConfig {
    fn default() -> Self {
        Self {
            months: 48,
            initial_nodes: 400,
            growth_per_month: 120,
            attach: 4,
            churn_frac: 0.01,
            burst_months: 5,
            burst_factor: 6.0,
            seed: 0x51E1,
        }
    }
}

impl WikiConfig {
    /// Scaled-down analogs of the paper's four datasets (Table 1). The paper
    /// runs 0.1M–2.2M nodes; these default to laptop scale and grow linearly
    /// with `scale`.
    pub fn preset(name: &str, scale: f64) -> Self {
        let base = Self::default();
        let s = |x: usize| ((x as f64) * scale).round().max(1.0) as usize;
        match name {
            // simple English: smallest, longest history
            "sen" => Self { months: 60, initial_nodes: s(300), growth_per_month: s(80), seed: 0xA11CE, ..base },
            // English: largest, shorter history
            "en" => Self { months: 38, initial_nodes: s(800), growth_per_month: s(400), seed: 0xB0B, ..base },
            "fr" => Self { months: 60, initial_nodes: s(500), growth_per_month: s(220), seed: 0xF4, ..base },
            "ge" => Self { months: 64, initial_nodes: s(500), growth_per_month: s(260), seed: 0x6E, ..base },
            _ => base,
        }
    }
}

/// A generated stream: initial graph, per-month deltas, and which months were
/// bursts (ground truth for sanity checks; the evaluation itself uses the
/// VEO proxy exactly like the paper).
#[derive(Debug)]
pub struct WikiStream {
    pub initial: Graph,
    pub deltas: Vec<DeltaGraph>,
    pub burst_months: Vec<usize>,
}

/// Generate a synthetic wiki stream.
pub fn wiki_stream(cfg: &WikiConfig) -> WikiStream {
    let mut rng = Pcg64::new(cfg.seed);
    // seed network: preferential attachment over initial_nodes
    let m0 = cfg.attach.max(2);
    let mut g = crate::generators::barabasi_albert(cfg.initial_nodes.max(m0 + 1), m0, &mut rng);

    // choose burst months (not the first month; spread out)
    let mut burst: Vec<usize> = Vec::new();
    if cfg.burst_months > 0 && cfg.months > 2 {
        let mut candidates: Vec<usize> = (1..cfg.months).collect();
        rng.shuffle(&mut candidates);
        burst = candidates.into_iter().take(cfg.burst_months).collect();
        burst.sort_unstable();
    }

    // degree-proportional target list for preferential attachment
    let mut targets: Vec<u32> = Vec::new();
    for (i, j, _) in g.edges() {
        targets.push(i);
        targets.push(j);
    }

    let mut deltas = Vec::with_capacity(cfg.months.saturating_sub(1));
    for month in 1..cfg.months {
        let is_burst = burst.contains(&month);
        let factor = if is_burst { cfg.burst_factor } else { 1.0 };
        let mut d = DeltaGraph::new();
        let n_now = g.num_nodes();

        // -- article growth --
        let grow = ((cfg.growth_per_month as f64) * factor).round() as usize;
        d.grow_nodes(grow);
        for k in 0..grow {
            let new_id = (n_now + k) as u32;
            let links = cfg.attach.max(1);
            for _ in 0..links {
                // mixed attachment (50% preferential / 50% uniform): real
                // hyperlink growth is far less hub-concentrated than pure BA
                // (hubs saturate), and this keeps s_max growing ∝ S.
                let t = if targets.is_empty() || rng.bernoulli(0.5) {
                    rng.below(n_now.max(1)) as u32
                } else {
                    targets[rng.below(targets.len())]
                };
                if t != new_id {
                    d.add(new_id, t, 1.0);
                    targets.push(new_id);
                    targets.push(t);
                }
            }
        }

        // -- steady celebrity inflow: popular articles accumulate links at a
        // near-constant monthly rate regardless of edit storms. This secular
        // signal dominates *unnormalized* dissimilarity metrics (λ-distance,
        // GED, DeltaCon affinities track the heaviest rows) and decouples
        // them from the bursty relative-change proxy — the failure mode the
        // paper reports for those baselines on real Wikipedia.
        let mut hubs: Vec<(u32, usize)> =
            (0..n_now as u32).map(|i| (i, g.degree(i))).collect();
        hubs.sort_by_key(|&(_, d)| std::cmp::Reverse(d));
        let inflow = (g.num_edges() as f64 * 0.02).round() as usize;
        for k in 0..inflow {
            let (hub, _) = hubs[k % 5.min(hubs.len())];
            let src = rng.below(n_now) as u32;
            if src != hub && !g.has_edge(src, hub) {
                d.add(src, hub, 1.0);
            }
        }

        // -- churn: delete some existing links, add fresh ones --
        let churn = ((g.num_edges() as f64) * cfg.churn_frac * factor).round() as usize;
        if churn > 0 && g.num_edges() > 0 {
            // deletions: sample uniform existing edges via reservoir over rows
            let mut deleted = 0usize;
            let mut guard = 0usize;
            while deleted < churn && guard < churn * 20 {
                guard += 1;
                let i = rng.below(n_now) as u32;
                let deg = g.degree(i);
                if deg == 0 {
                    continue;
                }
                let pick = rng.below(deg);
                if let Some((j, w)) = g.neighbors(i).nth(pick) {
                    d.add(i, j, -w);
                    deleted += 1;
                }
            }
            // additions: preferential endpoints
            for _ in 0..churn {
                let a = if targets.is_empty() {
                    rng.below(n_now) as u32
                } else {
                    targets[rng.below(targets.len())]
                };
                let b = rng.below(n_now) as u32;
                if a != b {
                    d.add(a, b, 1.0);
                }
            }
        }

        let d = d.coalesced();
        d.apply_to(&mut g);
        deltas.push(d);
    }

    // rebuild initial graph (generation mutated g); regenerate deterministically
    let mut rng2 = Pcg64::new(cfg.seed);
    let initial = crate::generators::barabasi_albert(cfg.initial_nodes.max(m0 + 1), m0, &mut rng2);
    WikiStream { initial, deltas, burst_months: burst }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphSequence;

    #[test]
    fn stream_materializes_consistently() {
        let cfg = WikiConfig { months: 6, initial_nodes: 50, growth_per_month: 10, ..Default::default() };
        let ws = wiki_stream(&cfg);
        assert_eq!(ws.deltas.len(), 5);
        let seq = GraphSequence::from_deltas(ws.initial.clone(), &ws.deltas);
        assert_eq!(seq.len(), 6);
        // monotone node growth
        for (a, b) in seq.pairs() {
            assert!(b.num_nodes() >= a.num_nodes());
            b.check_invariants().unwrap();
        }
        // growth target hit
        assert!(seq.get(5).num_nodes() >= 50 + 5 * 10);
    }

    #[test]
    fn bursts_have_bigger_deltas() {
        let cfg = WikiConfig {
            months: 20,
            initial_nodes: 100,
            growth_per_month: 20,
            burst_months: 3,
            burst_factor: 8.0,
            ..Default::default()
        };
        let ws = wiki_stream(&cfg);
        assert_eq!(ws.burst_months.len(), 3);
        let sizes: Vec<usize> = ws.deltas.iter().map(|d| d.num_changes()).collect();
        let burst_avg: f64 = ws
            .burst_months
            .iter()
            .map(|&m| sizes[m - 1] as f64)
            .sum::<f64>()
            / 3.0;
        let normal: Vec<f64> = (1..20)
            .filter(|m| !ws.burst_months.contains(m))
            .map(|m| sizes[m - 1] as f64)
            .collect();
        let normal_avg = normal.iter().sum::<f64>() / normal.len() as f64;
        assert!(burst_avg > 2.0 * normal_avg, "burst={burst_avg} normal={normal_avg}");
    }

    #[test]
    fn deterministic() {
        let cfg = WikiConfig { months: 5, initial_nodes: 40, growth_per_month: 5, ..Default::default() };
        let a = wiki_stream(&cfg);
        let b = wiki_stream(&cfg);
        assert_eq!(a.deltas.len(), b.deltas.len());
        for (x, y) in a.deltas.iter().zip(&b.deltas) {
            assert_eq!(x.edge_deltas(), y.edge_deltas());
        }
    }

    #[test]
    fn presets_differ() {
        let sen = WikiConfig::preset("sen", 1.0);
        let en = WikiConfig::preset("en", 1.0);
        assert!(en.growth_per_month > sen.growth_per_month);
        assert_ne!(sen.seed, en.seed);
    }

    #[test]
    fn deltas_include_deletions() {
        let cfg = WikiConfig { months: 10, initial_nodes: 200, churn_frac: 0.05, ..Default::default() };
        let ws = wiki_stream(&cfg);
        let has_negative = ws
            .deltas
            .iter()
            .any(|d| d.edge_deltas().iter().any(|&(_, _, dw)| dw < 0.0));
        assert!(has_negative, "expected deletion events in the stream");
    }
}
