//! Synthetic AS-level router peering snapshots ("Oregon-1-like") and the
//! paper's DoS-attack synthesis (Table 3/S2): pick one of the first 8
//! snapshots at random, connect X% of all nodes to one randomly chosen
//! target — the star-burst connection pattern of a botnet DoS.

use crate::graph::{Graph, GraphSequence};
use crate::util::Pcg64;

/// Configuration for the snapshot sequence.
#[derive(Debug, Clone)]
pub struct OregonConfig {
    /// Nodes per snapshot (Oregon-1 has ~10–11k).
    pub nodes: usize,
    /// Snapshots (the dataset has 9).
    pub snapshots: usize,
    /// BA attachment parameter (heavy-tailed degrees like AS graphs).
    pub attach: usize,
    /// Mean fraction of edges rewired between consecutive snapshots (drift).
    /// The realized per-step drift is uniform in [0.3, 1.7]× this mean, so a
    /// stealthy attack has to stand out against genuine drift variance (the
    /// regime where the paper's Table 3 separates methods).
    pub drift: f64,
    pub seed: u64,
}

impl Default for OregonConfig {
    fn default() -> Self {
        Self { nodes: 2000, snapshots: 9, attach: 2, drift: 0.02, seed: 0x0E60 }
    }
}

/// Generate the 9-snapshot sequence with mild drift.
pub fn oregon_snapshots(cfg: &OregonConfig) -> GraphSequence {
    let mut rng = Pcg64::new(cfg.seed);
    let mut g = crate::generators::barabasi_albert(cfg.nodes, cfg.attach, &mut rng);
    let mut snaps = Vec::with_capacity(cfg.snapshots);
    snaps.push(g.clone());
    for _ in 1..cfg.snapshots {
        let frac = cfg.drift * rng.uniform(0.3, 1.7);
        let rewire = ((g.num_edges() as f64) * frac).round() as usize;
        for _ in 0..rewire {
            // remove a random edge, add a random new one (degree-biased end)
            let i = rng.below(cfg.nodes) as u32;
            if g.degree(i) == 0 {
                continue;
            }
            let pick = rng.below(g.degree(i));
            let victim = g.neighbors(i).nth(pick).map(|(j, _)| j);
            if let Some(j) = victim {
                g.remove_edge(i, j);
            }
            let a = rng.below(cfg.nodes) as u32;
            let b = rng.below(cfg.nodes) as u32;
            if a != b {
                g.set_weight(a, b, 1.0);
            }
        }
        snaps.push(g.clone());
    }
    GraphSequence::from_snapshots(snaps)
}

/// A synthesized DoS event: the attacked sequence plus which consecutive-pair
/// indices the attack makes anomalous.
#[derive(Debug)]
pub struct DosEvent {
    pub seq: GraphSequence,
    /// 0-based index of the attacked snapshot.
    pub attacked_snapshot: usize,
    /// Consecutive-pair score indices affected by the attack.
    pub affected_pairs: Vec<usize>,
}

/// Inject a DoS pattern into a copy of `seq`: connect `x_frac` of all nodes
/// to one random target inside one random snapshot among the first 8.
pub fn dos_inject(seq: &GraphSequence, x_frac: f64, rng: &mut Pcg64) -> DosEvent {
    assert!(seq.len() >= 2);
    let k = rng.below((seq.len() - 1).min(8)); // one of the first 8
    let mut snaps: Vec<Graph> = seq.iter().cloned().collect();
    let g = &mut snaps[k];
    let n = g.num_nodes();
    let target = rng.below(n) as u32;
    let count = ((n as f64) * x_frac).round() as usize;
    let sources = rng.sample_distinct(n, count.min(n));
    for s in sources {
        let s = s as u32;
        if s != target {
            g.set_weight(s, target, 1.0);
        }
    }
    let mut affected = Vec::new();
    if k > 0 {
        affected.push(k - 1); // pair (k-1, k)
    }
    if k + 1 < snaps.len() {
        affected.push(k); // pair (k, k+1)
    }
    DosEvent { seq: GraphSequence::from_snapshots(snaps), attacked_snapshot: k, affected_pairs: affected }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshots_shape() {
        let cfg = OregonConfig { nodes: 300, ..Default::default() };
        let seq = oregon_snapshots(&cfg);
        assert_eq!(seq.len(), 9);
        for g in seq.iter() {
            assert_eq!(g.num_nodes(), 300);
            g.check_invariants().unwrap();
        }
    }

    #[test]
    fn drift_changes_edges_mildly() {
        let cfg = OregonConfig { nodes: 400, drift: 0.02, ..Default::default() };
        let seq = oregon_snapshots(&cfg);
        let d = crate::distance::graph_edit_distance(seq.get(0), seq.get(1));
        assert!(d > 0.0);
        let m = seq.get(0).num_edges() as f64;
        assert!(d < 0.2 * m, "drift too large: {d} of {m}");
    }

    #[test]
    fn dos_inject_creates_star_burst() {
        let cfg = OregonConfig { nodes: 300, ..Default::default() };
        let seq = oregon_snapshots(&cfg);
        let mut rng = Pcg64::new(7);
        let ev = dos_inject(&seq, 0.10, &mut rng);
        let attacked = ev.seq.get(ev.attacked_snapshot);
        let clean = seq.get(ev.attacked_snapshot);
        let added = attacked.num_edges() as i64 - clean.num_edges() as i64;
        assert!(added > 20, "added={added}"); // ~10% of 300 minus collisions
        assert!(!ev.affected_pairs.is_empty());
        assert!(ev.affected_pairs.iter().all(|&p| p < seq.len() - 1));
    }

    #[test]
    fn dos_larger_x_more_edges() {
        let cfg = OregonConfig { nodes: 300, ..Default::default() };
        let seq = oregon_snapshots(&cfg);
        let e1 = dos_inject(&seq, 0.01, &mut Pcg64::new(1));
        let e2 = dos_inject(&seq, 0.10, &mut Pcg64::new(1));
        let added = |ev: &DosEvent| {
            ev.seq.get(ev.attacked_snapshot).num_edges() as i64
                - seq.get(ev.attacked_snapshot).num_edges() as i64
        };
        assert!(added(&e2) > added(&e1));
    }

    #[test]
    fn dos_does_not_mutate_original() {
        let cfg = OregonConfig { nodes: 200, ..Default::default() };
        let seq = oregon_snapshots(&cfg);
        let before: Vec<usize> = seq.iter().map(|g| g.num_edges()).collect();
        let _ = dos_inject(&seq, 0.05, &mut Pcg64::new(3));
        let after: Vec<usize> = seq.iter().map(|g| g.num_edges()).collect();
        assert_eq!(before, after);
    }
}
