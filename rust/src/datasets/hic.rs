//! Synthetic dynamic genomic contact-map sequence ("Hi-C-like").
//!
//! Stands in for the controlled-access chromatin contact maps of Liu et al.
//! 2018a (12 samples, ground-truth bifurcation at the 6th measurement). The
//! generator preserves the properties the paper's Fig 4 experiment hinges on:
//!
//! 1. the signal lives in edge *weights* over a constant banded support, so
//!    support-only metrics (GED, VEO, degree distributions) are blind to the
//!    true transition and lock onto a decoy support-noise dip placed late in
//!    the sequence (measurement 8, where the paper reports VEO detecting);
//! 2. the genome-wide rate of weight reorganization follows a V-profile with
//!    its minimum at the bifurcation (the system "commits" and momentarily
//!    freezes) — regime drift from fibroblast-like to myotube-like block
//!    structure is applied *proportionally to the same profile*, so every
//!    distribution-wide weight metric (JS distance) sees a TDS local minimum
//!    exactly there;
//! 3. a small set of "hub" bins oscillates in strength with an amplitude
//!    profile that dips early (measurement 3) — a confounder that dominates
//!    top-eigenvalue and degree-normalized methods (λ-dist, DeltaCon, RMD,
//!    VNGE-NL/GL) far more than the global entropy.

use crate::graph::{Graph, GraphSequence};
use crate::util::Pcg64;

/// Configuration for the synthetic Hi-C sequence.
#[derive(Debug, Clone)]
pub struct HicConfig {
    /// Matrix dimension (the real data is 2894 1Mb bins; default scaled).
    pub dim: usize,
    /// Number of samples T (the study has 12).
    pub samples: usize,
    /// Ground-truth bifurcation measurement, 1-based (the study: 6).
    pub bifurcation: usize,
    /// Banded-contact width (contacts decay with genomic distance).
    pub band: usize,
    /// Spurious support-noise dip location, 1-based (Fig 4's VEO detects 8).
    pub support_dip: usize,
    /// Hub-oscillation dip location, 1-based (decoy for spectral methods).
    pub hub_dip: usize,
    pub seed: u64,
}

impl Default for HicConfig {
    fn default() -> Self {
        Self {
            dim: 240,
            samples: 12,
            bifurcation: 6,
            band: 24,
            support_dip: 8,
            hub_dip: 3,
            seed: 0x41C,
        }
    }
}

/// V-shaped per-gap rate profile (gap t couples samples t and t+1, 1-based
/// t = 1..T−1), minimized around `center_1b` so the TDS — the average of the
/// two adjacent gaps — has its interior minimum exactly there.
fn rate_profile(t_pairs: usize, center_1b: usize, lo: f64, hi: f64) -> Vec<f64> {
    if center_1b == usize::MAX {
        return vec![0.0; t_pairs]; // disabled (probe/ablation)
    }
    (1..=t_pairs)
        .map(|t| {
            let d = (t as f64 - (center_1b as f64 - 0.5)).abs();
            let span = t_pairs as f64 / 2.0;
            lo + (hi - lo) * (d / span).min(1.0)
        })
        .collect()
}

/// Generate the contact-map graph sequence.
pub fn hic_sequence(cfg: &HicConfig) -> GraphSequence {
    let n = cfg.dim;
    let mut rng = Pcg64::new(cfg.seed);
    let t_pairs = cfg.samples - 1;

    // base banded contact weights: decay with genomic distance |i−j|
    let base_weight = |i: usize, j: usize| -> f64 {
        let d = i.abs_diff(j);
        if d == 0 || d > cfg.band {
            0.0
        } else {
            8.0 / (d as f64)
        }
    };

    // regime block structures (fibroblast-like A → myotube-like B)
    let blocks_a = 4usize;
    let blocks_b = 6usize;
    let contrast = 1.35; // same-block boost; mild so drift ≲ noise
    let block_boost = |i: usize, j: usize, blocks: usize| -> f64 {
        if i * blocks / n == j * blocks / n {
            contrast
        } else {
            1.0
        }
    };

    // Multiplicative reorganization walk: each step scales every contact by
    // (1 + r_t·ζ) with a FRESH unit field ζ and a deterministic step size
    // r_t that follows a V-profile bottoming at the bifurcation (the system
    // decelerates into commitment, then accelerates into the new fate).
    // Because steps are relative and JS aggregates thousands of edges, the
    // per-gap response concentrates tightly around r_t — a clean V with its
    // unique interior TDS minimum at the bifurcation.
    let step_rate = rate_profile(t_pairs, cfg.bifurcation, 0.015, 0.22);
    // support-noise V-profile (decoy for support-only metrics)
    let support_rate = rate_profile(t_pairs, cfg.support_dip, 0.0005, 0.02);
    // hub-oscillation amplitude V-profile (decoy for spectral methods).
    // Oscillation is *downward only* on three interior bins: the graph's
    // strength maximum and λ_max stay pinned at untouched bulk nodes, so the
    // FINGER entropies see only the (second-order) Q effect while top-6
    // eigenvalues and FaBP affinities move first-order.
    let hub_rate = rate_profile(t_pairs, cfg.hub_dip, 0.05, 0.6);
    let hubs: Vec<bool> = {
        let mut v = vec![false; n];
        for k in [n / 8, n / 4, 3 * n / 8, n / 2, 5 * n / 8, 3 * n / 4] {
            if k < n {
                v[k] = true;
            }
        }
        v
    };

    // cumulative multiplicative factor per banded slot, evolved by the walk
    let mut walk = vec![1.0f64; n * cfg.band.max(1)];
    let mut mix = 0.0f64; // cumulative regime mix ∈ [0,1]
    let mut hub_phase = 1.0f64;
    let mut snapshots = Vec::with_capacity(cfg.samples);

    for t in 0..cfg.samples {
        if t > 0 {
            let r = step_rate[t - 1];
            for v in walk.iter_mut() {
                // drift-free lognormal step: no clamp truncation, so the
                // per-gap response stays exactly proportional to r
                *v *= (r * rng.normal() - 0.5 * r * r).exp();
            }
            // pin the walk's RMS: keeps the field's second moment stationary
            // so scalar-entropy heuristics see no systematic drift (their
            // score is then pure realization noise + the hub decoy), while
            // pairwise distances still see the full ∝r per-step change.
            let rms =
                (walk.iter().map(|v| v * v).sum::<f64>() / walk.len() as f64).sqrt();
            if rms > 0.0 {
                for v in walk.iter_mut() {
                    *v /= rms;
                }
            }
            // uniform regime-mix advance: contributes a near-constant term
            // to every consecutive-pair gap, so it shifts no method's TDS
            // minimum (a ∝r schedule would hand scalar-entropy heuristics
            // the same V the distances see).
            mix += 1.0 / t_pairs as f64;
            hub_phase = -hub_phase;
        }
        let mix_t = mix.min(1.0);
        let hub_amp = if t == 0 { 0.0 } else { hub_rate[t - 1] };
        // ∈ [1−amp, 1]: dips below the bulk, never above it
        let hub_factor = 1.0 - hub_amp * (0.5 + 0.5 * hub_phase);
        // light-row oscillation (NL/GL decoy), same V-at-hub_dip schedule
        let light_factor = 1.0 - 0.8 * hub_amp * (0.5 - 0.5 * hub_phase);
        let mut g = Graph::new(n);
        for i in 0..n {
            for d in 1..=cfg.band {
                let j = i + d;
                if j >= n {
                    break;
                }
                let base = base_weight(i, j);
                // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
                if base == 0.0 {
                    continue;
                }
                let boost = (1.0 - mix_t) * block_boost(i, j, blocks_a)
                    + mix_t * block_boost(i, j, blocks_b);
                let light = i % 3 == 0 && j % 3 == 0;
                let w = if light {
                    // light-light contacts: small weights and small endpoint
                    // strengths. The NL/GL decoy oscillates them — their
                    // 1/(s_u·s_v) edge weighting amplifies this region ~81×
                    // relative to the heavy core, while Q (uniform weighting)
                    // barely registers it.
                    base * 0.25 * light_factor
                } else {
                    // heavy core carries the reorganization walk (the true
                    // signal): multiplicative response stays proportional to
                    // the step size with no additive-clipping distortion
                    base * boost * walk[i * cfg.band + (d - 1)]
                };
                g.set_weight(i as u32, j as u32, w);
            }
        }
        // hub decoy: scale hub rows down by hub_factor and redistribute the
        // removed weight uniformly over every edge. trace(L) is preserved
        // exactly and Σs² only changes second-order (so Q and the FINGER
        // entropies barely move), while the hub eigenvalues of W and L move
        // first-order — steering λ-dist / DeltaCon / RMD toward the hub dip.
        if hub_factor < 1.0 {
            let before = g.total_weight();
            for h in 0..n {
                if !hubs[h] {
                    continue;
                }
                let nbrs: Vec<(u32, f64)> = g.neighbors(h as u32).collect();
                for (j, w) in nbrs {
                    g.set_weight(h as u32, j, w * hub_factor);
                }
            }
            // restore trace(L) with a global rescale: Q and every
            // L_N-derived quantity are scale-invariant, so the decoy stays
            // (near-)invisible to the entropies while the *relative* hub
            // eigenvalues drop first-order.
            let after = g.total_weight();
            if after > 0.0 {
                let scale = before / after;
                let edges: Vec<(u32, u32, f64)> = g.edges().collect();
                for (i, j, w) in edges {
                    g.set_weight(i, j, w * scale);
                }
            }
        }
        // sparse long-range support noise (fresh random positions per sample)
        if t > 0 {
            let count = (support_rate[t - 1] * n as f64 * 6.0).round() as usize;
            for _ in 0..count {
                let i = rng.below(n) as u32;
                let mut j = rng.below(n) as u32;
                if i == j {
                    j = (j + 1) % n as u32;
                }
                g.set_weight(i, j, 0.3);
            }
        }
        snapshots.push(g);
    }
    GraphSequence::from_snapshots(snapshots)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_shape() {
        let cfg = HicConfig { dim: 80, band: 10, ..Default::default() };
        let seq = hic_sequence(&cfg);
        assert_eq!(seq.len(), 12);
        for g in seq.iter() {
            assert_eq!(g.num_nodes(), 80);
            assert!(g.num_edges() > 0);
            g.check_invariants().unwrap();
        }
    }

    #[test]
    fn weights_change_support_mostly_stable() {
        let cfg = HicConfig { dim: 80, band: 10, ..Default::default() };
        let seq = hic_sequence(&cfg);
        let (a, b) = (seq.get(0), seq.get(1));
        let mut weight_changed = 0;
        for (i, j, w) in a.edges() {
            if (j - i) as usize <= 10 {
                assert!(b.has_edge(i, j), "banded support must persist");
                if (b.weight(i, j) - w).abs() > 1e-9 {
                    weight_changed += 1;
                }
            }
        }
        assert!(weight_changed > 100, "weights must carry the signal");
    }

    #[test]
    fn rate_profile_dips_at_center() {
        let p = rate_profile(11, 6, 0.1, 1.0);
        let min_idx =
            p.iter().enumerate().min_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert!(min_idx == 4 || min_idx == 5, "min at {min_idx}");
        assert!(p[0] > p[4] && p[10] > p[5]);
    }

    #[test]
    fn js_tds_minimum_at_ground_truth() {
        // the headline property: FINGER-JS TDS local min at measurement 6
        let cfg = HicConfig { dim: 100, band: 12, ..Default::default() };
        let seq = hic_sequence(&cfg);
        let theta = crate::anomaly::consecutive_scores(&seq, |a, b| {
            crate::distance::jsdist_fast(a, b)
        });
        let tds = crate::anomaly::temporal_difference_score(&theta);
        let bifs = crate::anomaly::detect_bifurcations(&tds);
        // 1-based measurement 6 = 0-based index 5
        assert!(bifs.contains(&5), "bifurcations at {bifs:?}, tds={tds:?}");
    }

    #[test]
    fn support_metrics_miss_the_bifurcation() {
        let cfg = HicConfig { dim: 100, band: 12, ..Default::default() };
        let seq = hic_sequence(&cfg);
        let theta = crate::anomaly::consecutive_scores(&seq, |a, b| {
            crate::distance::graph_edit_distance(a, b)
        });
        let tds = crate::anomaly::temporal_difference_score(&theta);
        let bifs = crate::anomaly::detect_bifurcations(&tds);
        assert!(!bifs.contains(&5), "GED should miss measurement 6: {bifs:?}");
    }

    #[test]
    fn deterministic() {
        let cfg = HicConfig { dim: 60, band: 8, ..Default::default() };
        let a = hic_sequence(&cfg);
        let b = hic_sequence(&cfg);
        for t in 0..a.len() {
            assert_eq!(a.get(t).num_edges(), b.get(t).num_edges());
        }
    }
}

