//! Synthetic workload generators standing in for the paper's proprietary /
//! controlled-access datasets (DESIGN.md §2 documents each substitution):
//!
//! * `wiki`   — evolving hyperlink networks (Table 1/2, Fig 3/S4 analog)
//! * `hic`    — dynamic genomic contact maps (Fig 4 analog)
//! * `oregon` — AS router snapshots + DoS injection (Table 3/S2 analog)

pub mod hic;
pub mod oregon;
pub mod wiki;

pub use hic::{hic_sequence, HicConfig};
pub use oregon::{dos_inject, oregon_snapshots, OregonConfig};
pub use wiki::{wiki_stream, WikiConfig, WikiStream};
