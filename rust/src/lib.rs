//! # FINGER — Fast Incremental von Neumann Graph Entropy
//!
//! Production-grade reproduction of *Chen, Wu, Liu, Rajapakse — "Fast
//! Incremental von Neumann Graph Entropy Computation: Theory, Algorithm, and
//! Applications" (ICML 2019)* as a three-layer Rust + JAX + Pallas stack.
//!
//! * **L3 (this crate)** — the streaming graph-sequence coordinator: graph
//!   substrate, exact and approximate VNGE, Jensen–Shannon graph distance,
//!   eleven baseline dissimilarity methods, anomaly/bifurcation evaluation,
//!   a threaded streaming pipeline, a sharded multi-session scoring service
//!   (`service`), a TCP front end + load driver putting that service on a
//!   socket (`net` — a typed `Command`/`Reply` core with two pluggable wire
//!   codecs negotiated per connection: the `nc`-friendly text line protocol
//!   and a length-prefixed binary framing for high-rate feeds, see
//!   `docs/PROTOCOL.md`), and a PJRT runtime that executes
//!   AOT-compiled XLA artifacts (built once by `make artifacts`; gated
//!   behind the `xla` cargo feature).
//! * **L2 (python/compile/model.py)** — dense JAX compute graphs (Q-statistics,
//!   FINGER-Ĥ, JS distance) lowered to HLO text at fixed sizes.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (tiled mat-vec and
//!   fused Q-statistics reduction) called from the L2 graphs.
//!
//! Python never runs on the request path; the binary is self-contained after
//! `make artifacts`.
//!
//! ## Quick start
//!
//! ```
//! use finger::entropy::{exact_vnge, finger_hhat, finger_htilde};
//! use finger::generators;
//! use finger::util::Pcg64;
//!
//! let mut rng = Pcg64::new(7);
//! let g = generators::erdos_renyi(200, 0.05, &mut rng);
//! let h = exact_vnge(&g);          // O(n³) baseline
//! let h_hat = finger_hhat(&g);     // O(n+m), Eq. (1)
//! let h_til = finger_htilde(&g);   // O(n+m), Eq. (2), incremental-friendly
//! assert!(h_til <= h_hat + 1e-12 && h_hat <= h + 1e-9);
//! ```

pub mod anomaly;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod datasets;
pub mod distance;
pub mod durability;
pub mod entropy;
pub mod fault;
pub mod generators;
pub mod graph;
pub mod linalg;
pub mod lint;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod service;
pub mod stream;
pub mod util;

pub use graph::{DeltaGraph, Graph, GraphSequence};
