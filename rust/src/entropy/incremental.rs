//! `FingerState` — the O(Δn+Δm) incremental VNGE engine (Theorem 2 + Eq. 3).
//!
//! The state tracks (Q, c, s_max) plus the underlying graph (whose per-node
//! strengths and per-edge weights the ΔQ formula reads). `preview` evaluates
//! H̃(G ⊕ ΔG) without committing — Algorithm 2 needs H̃ at G ⊕ ΔG/2 and
//! G ⊕ ΔG from the same base state.
//!
//! Two s_max policies:
//! * **Exact** (default): a strength multiset keeps s_max exact under weight
//!   decreases/deletions too, at O(log n) per touched node. The paper's
//!   Δs_max = max(0, max_{i∈Δ𝒱}(sᵢ+Δsᵢ) − s_max) rule never decreases s_max,
//!   which drifts on deletion-heavy streams.
//! * **PaperFaithful**: the paper's monotone rule, O(1) per touched node.
//!
//! Every preview/commit entry point comes in two flavors: the plain methods
//! (`preview`/`apply`/`apply_previewed`) allocate their transient buffers per
//! call, while the `*_with` variants thread a caller-owned [`Scratch`]
//! workspace so a steady-state scoring loop allocates nothing. Both flavors
//! run the same code on the same values — results are bit-for-bit identical.

use crate::graph::delta::CoalesceBuf;
use crate::graph::{DeltaGraph, Graph};
use std::collections::BTreeMap;

/// Reusable buffers for one Theorem-2 preview/commit evaluation. Every
/// buffer is cleared before use, so a reused instance computes bit-for-bit
/// the same result as a fresh one — reuse only skips the allocations.
#[derive(Debug, Clone, Default)]
pub(crate) struct PreviewBufs {
    /// Stable-coalesce workspace for non-normal-form deltas.
    coalesce: CoalesceBuf,
    /// Coalesced view of a non-normal-form delta.
    coalesced: Vec<(u32, u32, f64)>,
    /// Raw (node, Δs) pushes, two per edge delta.
    pushes: Vec<(u32, f64)>,
    /// Per-node net strength changes (merged `pushes`).
    dstrength: Vec<(u32, f64)>,
    /// Raw (strength-bits, ±1) multiset adjustments (Exact s_max preview).
    adj_pushes: Vec<(u64, i64)>,
    /// Merged multiset adjustments.
    adj: Vec<(u64, i64)>,
    /// Sorted, deduplicated touched-node ids (Exact commit).
    touched: Vec<u32>,
}

/// Reusable scratch workspace for the allocation-free scoring hot path:
/// holds the mid-point ΔG/2 buffer plus the preview/commit buffers that
/// `preview`/`apply`/`jsdist_incremental` would otherwise allocate per call.
/// One `Scratch` per scorer (or per thread); it carries no state between
/// calls, so `*_with` results are bit-identical to the allocating wrappers.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    pub(crate) half: DeltaGraph,
    pub(crate) bufs: PreviewBufs,
}

impl Scratch {
    /// Split into the mid-point delta buffer and the preview buffers, so the
    /// Algorithm-2 loop can preview the half delta it just wrote into the
    /// same workspace.
    pub(crate) fn split(&mut self) -> (&mut DeltaGraph, &mut PreviewBufs) {
        (&mut self.half, &mut self.bufs)
    }
}

/// s_max maintenance policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SmaxPolicy {
    /// Exact s_max via a strength multiset (handles deletions).
    #[default]
    Exact,
    /// The paper's monotone update rule (Eq. after Theorem 2).
    PaperFaithful,
}

/// Incrementally-maintained FINGER quantities for a single evolving graph.
#[derive(Debug, Clone)]
pub struct FingerState {
    graph: Graph,
    /// Quadratic proxy Q of the current graph.
    q: f64,
    /// Trace normalization c = 1/S (f64::INFINITY when S = 0).
    s_total: f64,
    s_max: f64,
    policy: SmaxPolicy,
    /// Multiset of positive strengths (bit-packed keys; strengths are ≥ 0 so
    /// `f64::to_bits` is order-preserving). Only kept for `Exact`.
    strengths: BTreeMap<u64, u32>,
    /// Number of committed deltas (for observability).
    steps: u64,
}

impl FingerState {
    /// Build from an initial graph. O(n+m).
    pub fn new(graph: Graph) -> Self {
        Self::with_policy(graph, SmaxPolicy::default())
    }

    pub fn with_policy(graph: Graph, policy: SmaxPolicy) -> Self {
        let q = crate::entropy::quadratic_q(&graph);
        let s_total = graph.total_weight();
        let s_max = graph.s_max();
        let mut state =
            Self { graph, q, s_total, s_max, policy, strengths: BTreeMap::new(), steps: 0 };
        if policy == SmaxPolicy::Exact {
            state.rebuild_strength_multiset();
        }
        state
    }

    /// The current graph (read-only).
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn q(&self) -> f64 {
        self.q
    }

    pub fn s_total(&self) -> f64 {
        self.s_total
    }

    pub fn c(&self) -> f64 {
        if self.s_total > 0.0 {
            1.0 / self.s_total
        } else {
            0.0
        }
    }

    pub fn s_max(&self) -> f64 {
        self.s_max
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn policy(&self) -> SmaxPolicy {
        self.policy
    }

    /// Total multiplicity stored in the strength multiset (Exact policy
    /// only; always 0 under PaperFaithful). When the state is consistent this
    /// equals the number of positive-strength nodes in the graph.
    pub fn strength_multiset_len(&self) -> usize {
        self.strengths.values().map(|&c| c as usize).sum()
    }

    /// Current H̃(G) (Eq. 2) from the maintained parts. O(1).
    pub fn htilde(&self) -> f64 {
        crate::entropy::htilde_from_parts(self.q, self.c(), self.s_max)
    }

    /// Theorem 2: compute (Q′, c′, s_max′) for G ⊕ ΔG **without committing**.
    /// O(Δn + Δm). The preview s_max uses the paper's monotone rule (exact
    /// recomputation without commit would be O(n)); on commit the `Exact`
    /// policy corrects it. Allocates transient buffers — the scoring hot
    /// path passes a reusable workspace via [`FingerState::preview_with`].
    pub fn preview(&self, delta: &DeltaGraph) -> PreviewedState {
        self.preview_bufs(delta, true, &mut PreviewBufs::default())
    }

    /// `preview` reusing `scratch`'s buffers: bit-identical result, zero
    /// allocations once the buffers have grown to the working-set size.
    pub fn preview_with(&self, delta: &DeltaGraph, scratch: &mut Scratch) -> PreviewedState {
        self.preview_bufs(delta, true, &mut scratch.bufs)
    }

    // lint: hot-path
    pub(crate) fn preview_bufs(
        &self,
        delta: &DeltaGraph,
        want_smax: bool,
        bufs: &mut PreviewBufs,
    ) -> PreviewedState {
        // Coalesce duplicate (i,j) entries before anything clamps: the clamp
        // below must see the *net* per-edge delta, matching what
        // `coalesced().apply_to(..)` / a single `Graph::add_weight` call
        // does. Clamping each duplicate independently against the same w_old
        // diverges whenever a delta over-deletes and then re-adds an edge.
        // Deltas already in coalesced normal form (the pipeline/service hot
        // path) are used in place — O(Δ) check, no copy; anything else gets
        // the O(Δ log Δ) stable sort + merge shared with `coalesced()`.
        let edges: &[(u32, u32, f64)] = if delta.is_sorted_unique() {
            delta.edge_deltas()
        } else {
            bufs.coalesce.coalesce_into(delta.edge_deltas(), &mut bufs.coalesced);
            &bufs.coalesced
        };
        // ΔQ = 2Σ sᵢΔsᵢ + Σ Δsᵢ² + 4Σ wᵢⱼΔwᵢⱼ + 2Σ Δwᵢⱼ²  (Theorem 2),
        // where sᵢ, wᵢⱼ are values in G and Δsᵢ the *net* strength change.
        // Per-node net strength changes, accumulated by push + sort + merge:
        // O(Δ log Δ), cache-friendly for both the 10-edge streaming windows
        // and the thousands-edge monthly batches.
        bufs.pushes.clear();
        let mut edge_terms = 0.0;
        for &(i, j, dw) in edges {
            let w_old = if (i as usize) < self.graph.num_nodes()
                && (j as usize) < self.graph.num_nodes()
            {
                self.graph.weight(i, j)
            } else {
                0.0
            };
            // Clamp like Graph::add_weight does: weights cannot go negative.
            let dw_eff = if w_old + dw < 0.0 { -w_old } else { dw };
            edge_terms += 4.0 * w_old * dw_eff + 2.0 * dw_eff * dw_eff;
            bufs.pushes.push((i, dw_eff));
            bufs.pushes.push((j, dw_eff));
        }
        bufs.pushes.sort_unstable_by_key(|&(node, _)| node);
        bufs.dstrength.clear();
        for &(node, ds) in &bufs.pushes {
            match bufs.dstrength.last_mut() {
                Some((last, acc)) if *last == node => *acc += ds,
                _ => bufs.dstrength.push((node, ds)),
            }
        }
        let mut node_terms = 0.0;
        let mut smax_candidate = 0.0f64;
        let mut delta_s_eff = 0.0;
        for &(i, ds) in &bufs.dstrength {
            let s_old =
                if (i as usize) < self.graph.num_nodes() { self.graph.strength(i) } else { 0.0 };
            node_terms += 2.0 * s_old * ds + ds * ds;
            smax_candidate = smax_candidate.max(s_old + ds);
            delta_s_eff += ds;
        }
        let dq = node_terms + edge_terms;
        let (q_new, s_new) = if self.s_total > 0.0 {
            let c = 1.0 / self.s_total;
            // Use the effective (clamp-aware) ΔS for consistency with dq.
            let s_new = self.s_total + delta_s_eff;
            let denom = 1.0 + c * delta_s_eff;
            if denom <= 0.0 || s_new <= 0.0 {
                (0.0, 0.0) // graph emptied
            } else {
                let q = (self.q - 1.0) / (denom * denom) - (c / denom).powi(2) * dq + 1.0;
                (q, s_new)
            }
        } else {
            // starting from an empty graph: compute Q′ from scratch terms
            let s_new = delta_s_eff;
            if s_new <= 0.0 {
                (0.0, 0.0)
            } else {
                let c_new = 1.0 / s_new;
                // Q′ = 1 − c′²(Σ s′² + 2Σ w′²); from empty graph dq collects
                // exactly Σ Δs² + 2Σ Δw².
                (1.0 - c_new * c_new * dq, s_new)
            }
        };
        // s_max′: the paper's monotone rule, or an exact O(Δ log n)
        // adjustment scan over the strength multiset under `Exact`.
        let s_max_new = match self.policy {
            _ if !want_smax => 0.0, // caller recomputes (apply's Exact path)
            SmaxPolicy::PaperFaithful => self.s_max.max(smax_candidate),
            SmaxPolicy::Exact => {
                bufs.adj_pushes.clear();
                for &(i, ds) in &bufs.dstrength {
                    let s_old = if (i as usize) < self.graph.num_nodes() {
                        self.graph.strength(i)
                    } else {
                        0.0
                    };
                    if s_old > 0.0 {
                        bufs.adj_pushes.push((s_old.to_bits(), -1));
                    }
                    let s_new_i = s_old + ds;
                    if s_new_i > 0.0 {
                        bufs.adj_pushes.push((s_new_i.to_bits(), 1));
                    }
                }
                bufs.adj_pushes.sort_unstable_by_key(|&(k, _)| k);
                bufs.adj.clear();
                for &(k, d) in &bufs.adj_pushes {
                    match bufs.adj.last_mut() {
                        Some((last, acc)) if *last == k => *acc += d,
                        _ => bufs.adj.push((k, d)),
                    }
                }
                let mut best = 0.0f64;
                // candidates introduced (or still positive) among touched keys
                for &(bits, d) in &bufs.adj {
                    let eff = self.strengths.get(&bits).map(|&c| c as i64).unwrap_or(0) + d;
                    if eff > 0 {
                        best = best.max(f64::from_bits(bits));
                    }
                }
                // top of the untouched multiset
                for (&bits, &cnt) in self.strengths.iter().rev() {
                    let eff = cnt as i64
                        + bufs
                            .adj
                            .binary_search_by_key(&bits, |&(k, _)| k)
                            .map(|idx| bufs.adj[idx].1)
                            .unwrap_or(0);
                    if eff > 0 {
                        best = best.max(f64::from_bits(bits));
                        break;
                    }
                }
                best
            }
        };
        PreviewedState { q: q_new, s_total: s_new, s_max: s_max_new }
    }
    // lint: hot-path end

    /// H̃(G ⊕ ΔG) without committing (Algorithm 2 line 1). O(Δn + Δm).
    pub fn htilde_after(&self, delta: &DeltaGraph) -> f64 {
        let p = self.preview(delta);
        p.htilde()
    }

    /// Commit ΔG: G ← G ⊕ ΔG, updating Q via Theorem 2 and s_max per policy.
    /// O(Δn + Δm) (Exact policy adds O(log n) per touched node). Allocates
    /// transient buffers — the hot path uses [`FingerState::apply_with`].
    pub fn apply(&mut self, delta: &DeltaGraph) {
        self.apply_bufs(delta, &mut PreviewBufs::default());
    }

    /// `apply` reusing `scratch`'s buffers: bit-identical state transition,
    /// zero allocations in steady state.
    pub fn apply_with(&mut self, delta: &DeltaGraph, scratch: &mut Scratch) {
        self.apply_bufs(delta, &mut scratch.bufs);
    }

    fn apply_bufs(&mut self, delta: &DeltaGraph, bufs: &mut PreviewBufs) {
        // Exact policy recomputes s_max from the multiset below, so skip the
        // preview's O(Δ log n) s_max adjustment scan on that path.
        let preview = self.preview_bufs(delta, self.policy == SmaxPolicy::PaperFaithful, bufs);
        self.apply_previewed_bufs(delta, preview, bufs);
    }

    /// Commit ΔG reusing an already-computed `preview(delta)` result
    /// (Algorithm 2 previews ΔG for its score anyway — one preview saved).
    pub fn apply_previewed(&mut self, delta: &DeltaGraph, preview: PreviewedState) {
        self.apply_previewed_bufs(delta, preview, &mut PreviewBufs::default());
    }

    /// `apply_previewed` reusing `scratch`'s buffers.
    pub fn apply_previewed_with(
        &mut self,
        delta: &DeltaGraph,
        preview: PreviewedState,
        scratch: &mut Scratch,
    ) {
        self.apply_previewed_bufs(delta, preview, &mut scratch.bufs);
    }

    // lint: hot-path
    pub(crate) fn apply_previewed_bufs(
        &mut self,
        delta: &DeltaGraph,
        preview: PreviewedState,
        bufs: &mut PreviewBufs,
    ) {
        // The preview coalesces duplicate (i,j) entries internally; mutate
        // the graph through the same coalesced view. Sequential re-clamping
        // of an over-deleting duplicate would disagree with the previewed Q.
        // The O(Δ) normal-form check suffices: coalescing a delta that is
        // merely unsorted (but duplicate-free) is semantically a no-op, so
        // over-triggering on such deltas costs a sort, never correctness.
        let new_nodes = delta.new_nodes();
        let edges: &[(u32, u32, f64)] = if delta.is_sorted_unique() {
            delta.edge_deltas()
        } else {
            bufs.coalesce.coalesce_into(delta.edge_deltas(), &mut bufs.coalesced);
            &bufs.coalesced
        };
        // capture strengths of touched nodes before mutation (Exact policy);
        // sort + dedup in the reusable buffer (multiset removal/insertion is
        // per-node commutative, so the order does not matter)
        bufs.touched.clear();
        let mut multiset_miss = false;
        if self.policy == SmaxPolicy::Exact {
            for &(i, j, _) in edges {
                bufs.touched.push(i);
                bufs.touched.push(j);
            }
            bufs.touched.sort_unstable();
            bufs.touched.dedup();
            for &i in &bufs.touched {
                if (i as usize) < self.graph.num_nodes() {
                    multiset_miss |= !self.remove_strength(self.graph.strength(i));
                }
            }
        }
        // G ← G ⊕ ΔG through the same coalesced view (the logic of
        // `DeltaGraph::apply_to`, inlined over the scratch slice).
        let need = edges
            .iter()
            .map(|&(i, j, _)| i.max(j) as usize + 1)
            .max()
            .unwrap_or(0)
            .max(self.graph.num_nodes() + new_nodes);
        self.graph.ensure_nodes(need);
        for &(i, j, dw) in edges {
            self.graph.add_weight(i, j, dw);
        }
        self.q = preview.q;
        self.s_total = preview.s_total;
        match self.policy {
            SmaxPolicy::PaperFaithful => {
                self.s_max = preview.s_max;
            }
            SmaxPolicy::Exact => {
                for &i in &bufs.touched {
                    self.insert_strength(self.graph.strength(i));
                }
                if multiset_miss {
                    // A removal found no usable key: the multiset has drifted
                    // from the graph's strength cache, and a stale key would
                    // inflate s_max forever. Rebuild wholesale — O(n), but
                    // only on detected drift.
                    self.rebuild_strength_multiset();
                }
                self.s_max = self
                    .strengths
                    .keys()
                    .next_back()
                    .map(|&b| f64::from_bits(b))
                    .unwrap_or(0.0);
            }
        }
        self.steps += 1;
    }
    // lint: hot-path end

    /// Remove one occurrence of strength `s` from the multiset. Returns
    /// false when `s` is positive but neither its exact bit-key nor a
    /// drift-close neighbor is stored — the caller must then resync the
    /// multiset, since a silent no-op would leave a stale key behind.
    fn remove_strength(&mut self, s: f64) -> bool {
        if s <= 0.0 {
            return true;
        }
        let key = s.to_bits();
        if self.decrement_strength_key(key) {
            return true;
        }
        // Exact-key miss (accumulated float drift between the graph's
        // strength cache and the multiset): fall back to the nearest stored
        // key, but only if it is close enough to plausibly be this strength.
        let below = self.strengths.range(..key).next_back().map(|(&k, _)| k);
        let above = self.strengths.range(key..).next().map(|(&k, _)| k);
        let nearest = match (below, above) {
            (Some(b), Some(a)) => {
                if (f64::from_bits(a) - s).abs() < (s - f64::from_bits(b)).abs() {
                    Some(a)
                } else {
                    Some(b)
                }
            }
            (b, a) => b.or(a),
        };
        match nearest {
            Some(k) if (f64::from_bits(k) - s).abs() <= 1e-9 * s.max(1.0) => {
                self.decrement_strength_key(k)
            }
            _ => false,
        }
    }

    fn decrement_strength_key(&mut self, key: u64) -> bool {
        if let Some(cnt) = self.strengths.get_mut(&key) {
            *cnt -= 1;
            if *cnt == 0 {
                self.strengths.remove(&key);
            }
            true
        } else {
            false
        }
    }

    fn insert_strength(&mut self, s: f64) {
        if s > 0.0 {
            *self.strengths.entry(s.to_bits()).or_insert(0) += 1;
        }
    }

    fn rebuild_strength_multiset(&mut self) {
        self.strengths.clear();
        for &s in self.graph.strengths() {
            if s > 0.0 {
                *self.strengths.entry(s.to_bits()).or_insert(0) += 1;
            }
        }
    }

    /// Rebuild Q/c/s_max from the stored graph (O(n+m)) — drift correction
    /// hook for long streams; returns the |ΔQ| correction applied.
    pub fn resync(&mut self) -> f64 {
        let q_fresh = crate::entropy::quadratic_q(&self.graph);
        let drift = (q_fresh - self.q).abs();
        *self = Self::with_policy(std::mem::take(&mut self.graph), self.policy);
        drift
    }
}

/// Previewed (Q′, c′, s_max′) for a hypothetical G ⊕ ΔG.
#[derive(Debug, Clone, Copy)]
pub struct PreviewedState {
    pub q: f64,
    pub s_total: f64,
    pub s_max: f64,
}

impl PreviewedState {
    pub fn c(&self) -> f64 {
        if self.s_total > 0.0 {
            1.0 / self.s_total
        } else {
            0.0
        }
    }

    /// H̃ from the previewed parts (Eq. 3).
    pub fn htilde(&self) -> f64 {
        crate::entropy::htilde_from_parts(self.q, self.c(), self.s_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;
    use crate::entropy::{finger_htilde, quadratic_q};
    use crate::generators;
    use crate::graph::ops;
    use crate::util::Pcg64;

    fn random_delta(g: &Graph, rng: &mut Pcg64, ops_count: usize) -> DeltaGraph {
        let n = g.num_nodes() as u32;
        let mut d = DeltaGraph::new();
        for _ in 0..ops_count {
            let i = rng.below(n as usize) as u32;
            let mut j = rng.below(n as usize) as u32;
            if i == j {
                j = (j + 1) % n;
            }
            match rng.below(3) {
                0 => d.add(i, j, rng.uniform(0.1, 2.0)),            // add/increase
                1 => d.add(i, j, -g.weight(i.min(j), i.max(j))),    // delete
                _ => d.add(i, j, rng.uniform(-0.5, 0.5)),           // perturb
            };
        }
        d.coalesced()
    }

    #[test]
    fn q_update_matches_scratch_single_delta() {
        let mut rng = Pcg64::new(1);
        let g = generators::erdos_renyi(60, 0.1, &mut rng);
        let mut state = FingerState::new(g.clone());
        let d = random_delta(&g, &mut rng, 15);
        state.apply(&d);
        let composed = ops::compose(&g, &d);
        let q_scratch = quadratic_q(&composed);
        assert!((state.q() - q_scratch).abs() < 1e-10, "{} vs {q_scratch}", state.q());
    }

    #[test]
    fn q_update_stable_over_long_stream() {
        let mut rng = Pcg64::new(2);
        let g = generators::erdos_renyi(50, 0.1, &mut rng);
        let mut state = FingerState::new(g);
        for _ in 0..500 {
            let d = random_delta(state.graph(), &mut rng, 5);
            state.apply(&d);
        }
        let q_scratch = quadratic_q(state.graph());
        assert!((state.q() - q_scratch).abs() < 1e-8, "{} vs {q_scratch}", state.q());
        state.graph().check_invariants().unwrap();
    }

    #[test]
    fn exact_policy_tracks_smax_under_deletions() {
        let mut g = Graph::new(4);
        g.set_weight(0, 1, 10.0);
        g.set_weight(2, 3, 1.0);
        let mut state = FingerState::new(g);
        assert_bits_eq!(state.s_max(), 10.0);
        let mut d = DeltaGraph::new();
        d.add(0, 1, -10.0); // delete heavy edge
        state.apply(&d);
        assert_bits_eq!(state.s_max(), 1.0); // exact policy decreases
        assert!((state.htilde() - finger_htilde(state.graph())).abs() < 1e-12);
    }

    #[test]
    fn paper_policy_never_decreases_smax() {
        let mut g = Graph::new(4);
        g.set_weight(0, 1, 10.0);
        g.set_weight(2, 3, 1.0);
        let mut state = FingerState::with_policy(g, SmaxPolicy::PaperFaithful);
        let mut d = DeltaGraph::new();
        d.add(0, 1, -10.0);
        state.apply(&d);
        assert_bits_eq!(state.s_max(), 10.0); // monotone rule keeps the stale max
    }

    #[test]
    fn htilde_matches_from_scratch_on_growth_stream() {
        // additions only: both policies should equal the from-scratch H̃
        let mut rng = Pcg64::new(3);
        let g = generators::erdos_renyi(40, 0.05, &mut rng);
        let mut state = FingerState::new(g);
        for _ in 0..50 {
            let n = state.graph().num_nodes() as u32;
            let mut d = DeltaGraph::new();
            let i = rng.below(n as usize) as u32;
            let j = (i + 1 + rng.below(n as usize - 1) as u32) % n;
            if i != j {
                d.add(i, j, rng.uniform(0.2, 1.5));
            }
            state.apply(&d);
            let fresh = finger_htilde(state.graph());
            assert!((state.htilde() - fresh).abs() < 1e-9, "{} vs {fresh}", state.htilde());
        }
    }

    #[test]
    fn preview_does_not_mutate() {
        let mut rng = Pcg64::new(4);
        let g = generators::erdos_renyi(30, 0.2, &mut rng);
        let state = FingerState::new(g.clone());
        let d = random_delta(&g, &mut rng, 10);
        let _ = state.preview(&d);
        assert_eq!(state.graph().num_edges(), g.num_edges());
        assert!((state.q() - quadratic_q(&g)).abs() < 1e-12);
    }

    #[test]
    fn preview_halved_matches_average_graph() {
        // Algorithm 2's G ⊕ ΔG/2 equals the averaged graph (G + G')/2
        let mut rng = Pcg64::new(5);
        let g = generators::erdos_renyi(40, 0.1, &mut rng);
        let d = random_delta(&g, &mut rng, 12);
        // use only additive part to avoid clamping asymmetries in this check
        let d = DeltaGraph::diff(&g, &ops::compose(&g, &d));
        let state = FingerState::new(g.clone());
        let p_half = state.preview(&d.half());
        let avg = crate::graph::ops::average_graph(&g, &ops::compose(&g, &d));
        assert!((p_half.q - quadratic_q(&avg)).abs() < 1e-9);
    }

    #[test]
    fn growth_from_empty_graph() {
        let mut state = FingerState::new(Graph::new(0));
        let mut d = DeltaGraph::new();
        d.grow_nodes(3).add(0, 1, 1.0).add(1, 2, 1.0);
        state.apply(&d);
        assert_eq!(state.graph().num_nodes(), 3);
        let q_scratch = quadratic_q(state.graph());
        assert!((state.q() - q_scratch).abs() < 1e-12, "{} vs {q_scratch}", state.q());
    }

    #[test]
    fn emptying_the_graph_resets() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
        let mut state = FingerState::new(g.clone());
        let mut d = DeltaGraph::new();
        d.add(0, 1, -1.0).add(1, 2, -1.0);
        state.apply(&d);
        assert_bits_eq!(state.s_total(), 0.0);
        assert_bits_eq!(state.htilde(), 0.0);
    }

    #[test]
    fn clamped_deletion_matches_graph_semantics() {
        // deleting more weight than exists must agree with Graph::add_weight
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        let mut state = FingerState::new(g.clone());
        let mut d = DeltaGraph::new();
        d.add(0, 1, -5.0); // over-delete
        state.apply(&d);
        let q_scratch = quadratic_q(state.graph());
        assert!((state.q() - q_scratch).abs() < 1e-12);
        assert_eq!(state.graph().num_edges(), 1);
    }

    #[test]
    fn resync_reports_zero_drift_after_exact_updates() {
        let mut rng = Pcg64::new(6);
        let g = generators::erdos_renyi(30, 0.15, &mut rng);
        let mut state = FingerState::new(g);
        for _ in 0..20 {
            let d = random_delta(state.graph(), &mut rng, 4);
            state.apply(&d);
        }
        let drift = state.resync();
        assert!(drift < 1e-9, "drift={drift}");
    }

    #[test]
    fn steps_counter() {
        let mut state = FingerState::new(Graph::new(2));
        let mut d = DeltaGraph::new();
        d.add(0, 1, 1.0);
        state.apply(&d);
        assert_eq!(state.steps(), 1);
    }

    #[test]
    fn uncoalesced_overdeleting_duplicates_match_coalesced_semantics() {
        // Regression: per-entry clamping against the same w_old used to
        // diverge from DeltaGraph::apply_to/Graph::add_weight semantics when
        // a delta contained duplicate (i,j) entries. Net delta here is -2.0
        // on an edge of weight 1.0 (clamped to removal); entry-wise clamping
        // would have computed -1.0 then +3.0 instead.
        for policy in [SmaxPolicy::Exact, SmaxPolicy::PaperFaithful] {
            let g = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 2.0)]);
            let mut state = FingerState::with_policy(g.clone(), policy);
            let mut d = DeltaGraph::new();
            d.add(0, 1, -5.0).add(0, 1, 3.0);
            state.apply(&d);
            let mut expect = g.clone();
            d.coalesced().apply_to(&mut expect);
            assert_eq!(state.graph().num_edges(), expect.num_edges(), "{policy:?}");
            assert!((state.graph().weight(0, 1) - expect.weight(0, 1)).abs() < 1e-15);
            let q_scratch = quadratic_q(state.graph());
            assert!(
                (state.q() - q_scratch).abs() < 1e-12,
                "{policy:?}: {} vs {q_scratch}",
                state.q()
            );
            assert!((state.s_total() - state.graph().total_weight()).abs() < 1e-12);
            if policy == SmaxPolicy::Exact {
                assert!((state.htilde() - finger_htilde(state.graph())).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn uncoalesced_delta_equals_precoalesced_apply_and_preview() {
        let mut rng = Pcg64::new(8);
        let g = generators::erdos_renyi(30, 0.15, &mut rng);
        let mut raw = DeltaGraph::new();
        for _ in 0..40 {
            let i = rng.below(30) as u32;
            let mut j = rng.below(30) as u32;
            if i == j {
                j = (j + 1) % 30;
            }
            raw.add(i, j, rng.uniform(-1.5, 1.0));
        }
        // guarantee an over-delete/re-add duplicate pair is present
        raw.add(0, 1, -10.0).add(0, 1, 0.7);
        assert!(raw.has_duplicate_edges());

        let base = FingerState::new(g.clone());
        let p_raw = base.preview(&raw);
        let p_coal = base.preview(&raw.coalesced());
        assert!((p_raw.q - p_coal.q).abs() < 1e-12);
        assert!((p_raw.s_total - p_coal.s_total).abs() < 1e-12);
        assert!((p_raw.s_max - p_coal.s_max).abs() < 1e-12);

        let mut a = FingerState::new(g.clone());
        a.apply(&raw);
        let mut b = FingerState::new(g);
        b.apply(&raw.coalesced());
        assert_eq!(a.graph().num_edges(), b.graph().num_edges());
        assert!((a.q() - b.q()).abs() < 1e-12);
        assert!((a.htilde() - b.htilde()).abs() < 1e-12);
        let q_scratch = quadratic_q(a.graph());
        assert!((a.q() - q_scratch).abs() < 1e-10, "{} vs {q_scratch}", a.q());
    }

    #[test]
    fn adversarial_add_remove_stream_keeps_multiset_consistent() {
        // Long adversarial stream of exact deletions, over-deletions and
        // re-adds, applied uncoalesced: under the Exact policy the strength
        // multiset must keep mirroring the graph (size == number of
        // positive-strength nodes, s_max exact) at every step.
        for policy in [SmaxPolicy::Exact, SmaxPolicy::PaperFaithful] {
            let mut rng = Pcg64::new(0xADD);
            let g = generators::erdos_renyi(12, 0.3, &mut rng);
            let mut state = FingerState::with_policy(g, policy);
            for step in 0..2000 {
                let n = state.graph().num_nodes();
                let mut d = DeltaGraph::new();
                for _ in 0..3 {
                    let i = rng.below(n) as u32;
                    let mut j = rng.below(n) as u32;
                    if i == j {
                        j = (j + 1) % n as u32;
                    }
                    let w_cur = state.graph().weight(i.min(j), i.max(j));
                    match rng.below(4) {
                        0 => d.add(i, j, rng.uniform(0.1, 2.0)),
                        1 => d.add(i, j, -w_cur),                 // exact delete
                        2 => d.add(i, j, -rng.uniform(0.5, 3.0)), // over-delete
                        _ => d.add(i, j, rng.uniform(-0.5, 0.5)),
                    };
                }
                state.apply(&d);
                if policy == SmaxPolicy::Exact {
                    let positive =
                        state.graph().strengths().iter().filter(|&&s| s > 0.0).count();
                    assert_eq!(state.strength_multiset_len(), positive, "step {step}");
                    assert!(
                        (state.s_max() - state.graph().s_max()).abs() < 1e-12,
                        "step {step}: {} vs {}",
                        state.s_max(),
                        state.graph().s_max()
                    );
                } else {
                    // the paper's monotone rule upper-bounds the true s_max
                    assert!(state.s_max() >= state.graph().s_max() - 1e-12, "step {step}");
                }
            }
            let q_scratch = quadratic_q(state.graph());
            assert!(
                (state.q() - q_scratch).abs() < 1e-6,
                "{policy:?}: {} vs {q_scratch}",
                state.q()
            );
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical_to_allocating_path() {
        // One Scratch shared across 60 steps and both policies: preview and
        // apply must produce bit-for-bit the same (q, s_total, s_max, H̃) as
        // the per-call-allocating wrappers, including on uncoalesced deltas.
        for policy in [SmaxPolicy::Exact, SmaxPolicy::PaperFaithful] {
            let mut rng = Pcg64::new(0x5C4A7C4);
            let g = generators::erdos_renyi(40, 0.12, &mut rng);
            let mut fresh = FingerState::with_policy(g.clone(), policy);
            let mut reused = FingerState::with_policy(g, policy);
            let mut scratch = Scratch::default();
            for step in 0..60 {
                let mut d = DeltaGraph::new();
                for _ in 0..6 {
                    let i = rng.below(40) as u32;
                    let mut j = rng.below(40) as u32;
                    if i == j {
                        j = (j + 1) % 40;
                    }
                    d.add(i, j, rng.uniform(-1.0, 1.0));
                }
                // every other step stays raw (duplicates possible) to force
                // the coalescing fallback through the scratch buffers too
                let d = if step % 2 == 0 { d.coalesced() } else { d };
                let p_fresh = fresh.preview(&d);
                let p_reused = reused.preview_with(&d, &mut scratch);
                assert_eq!(p_fresh.q.to_bits(), p_reused.q.to_bits(), "{policy:?} step {step}");
                assert_eq!(p_fresh.s_total.to_bits(), p_reused.s_total.to_bits());
                assert_eq!(p_fresh.s_max.to_bits(), p_reused.s_max.to_bits());
                if step % 3 == 0 {
                    fresh.apply_previewed(&d, p_fresh);
                    reused.apply_previewed_with(&d, p_reused, &mut scratch);
                } else {
                    fresh.apply(&d);
                    reused.apply_with(&d, &mut scratch);
                }
                assert_eq!(fresh.q().to_bits(), reused.q().to_bits(), "{policy:?} step {step}");
                assert_eq!(fresh.s_max().to_bits(), reused.s_max().to_bits());
                assert_eq!(fresh.htilde().to_bits(), reused.htilde().to_bits());
                assert_eq!(fresh.graph().num_edges(), reused.graph().num_edges());
            }
        }
    }

    #[test]
    fn multiset_drift_uses_nearest_key_fallback() {
        // Simulate accumulated float drift: nudge a stored key by one ulp so
        // the recomputed strength's bit-key misses. The removal must fall
        // back to the neighboring key instead of silently no-opping.
        let g = Graph::from_edges(4, &[(0, 1, 1.5), (2, 3, 0.5)]);
        let mut state = FingerState::new(g);
        let bits = 1.5f64.to_bits();
        let cnt = state.strengths.remove(&bits).unwrap();
        state.strengths.insert(bits + 1, cnt); // 1.5 + 1 ulp
        let mut d = DeltaGraph::new();
        d.add(0, 1, -1.5); // delete the heavy edge: removes strength 1.5 twice
        state.apply(&d);
        assert_eq!(state.strength_multiset_len(), 2); // nodes 2 and 3
        assert_bits_eq!(state.s_max(), 0.5);
        assert!((state.htilde() - finger_htilde(state.graph())).abs() < 1e-12);
    }

    #[test]
    fn multiset_hard_miss_triggers_rebuild() {
        // A far-off stale key cannot be matched by the nearest-key fallback;
        // the miss must trigger a full multiset rebuild so the stale key
        // stops inflating s_max.
        let g = Graph::from_edges(4, &[(0, 1, 2.0), (2, 3, 1.0)]);
        let mut state = FingerState::new(g);
        state.strengths.remove(&2.0f64.to_bits());
        state.strengths.insert(100.0f64.to_bits(), 2); // stale keys
        assert_bits_eq!(state.s_max(), 2.0); // cached s_max still sane pre-apply
        let mut d = DeltaGraph::new();
        d.add(0, 1, 1.0);
        state.apply(&d);
        let positive = state.graph().strengths().iter().filter(|&&s| s > 0.0).count();
        assert_eq!(state.strength_multiset_len(), positive);
        assert_bits_eq!(state.s_max(), state.graph().s_max()); // 3.0, stale 100 purged
    }
}
