//! The two approximate-VNGE heuristics the paper compares against (Table 2,
//! Table 3, Fig 4): VNGE-NL (Han et al. 2012, normalized Laplacian) and
//! VNGE-GL (Ye et al. 2014, generalized Laplacian of directed graphs).
//! Both are O(n+m) quadratic-approximation formulas *without* an
//! approximation guarantee — that absence is the paper's point.

use crate::graph::Graph;
use crate::linalg::SymMatrix;

/// VNGE-NL (Han et al. 2012): quadratic approximation of the von Neumann
/// entropy computed from the symmetric normalized Laplacian with density
/// matrix 𝓛/n:
///
///   H_NL ≈ 1 − 1/n − (1/n²)·Σ_{(u,v)∈E} w_uv² / (s_u·s_v)
///
/// (for unweighted graphs this is the published 1 − 1/n − (1/n²)Σ 1/(d_u d_v)).
pub fn vnge_nl(g: &Graph) -> f64 {
    let n = g.num_nodes() as f64;
    if n < 1.0 || g.num_edges() == 0 {
        return 0.0;
    }
    let mut sum = 0.0;
    for (u, v, w) in g.edges() {
        let su = g.strength(u);
        let sv = g.strength(v);
        if su > 0.0 && sv > 0.0 {
            sum += (w * w) / (su * sv);
        }
    }
    1.0 - 1.0 / n - sum / (n * n)
}

/// VNGE-GL (Ye et al. 2014): quadratic approximation for the generalized
/// (directed) Laplacian. An undirected edge is treated as two opposite arcs,
/// so in-strength = out-strength = s; Ye et al.'s two-term arc sum then
/// reduces to the NL kernel plus an out-degree self term:
///
///   H_GL ≈ 1 − 1/n − (1/(2n²))·Σ_{arcs (u→v)} [ w²/(s_u s_v) + w²/s_u² ]
///        = 1 − 1/n − (1/n²)·[ Σ_{(u,v)∈E} w²/(s_u s_v)
///                             + ½·Σ_{(u,v)∈E} w²·(1/s_u² + 1/s_v²) ]
///
/// Documented adaptation (DESIGN.md §2): the original is defined on digraphs;
/// this is its exact value on the bidirected version of an undirected graph.
pub fn vnge_gl(g: &Graph) -> f64 {
    let n = g.num_nodes() as f64;
    if n < 1.0 || g.num_edges() == 0 {
        return 0.0;
    }
    let mut cross = 0.0;
    let mut self_term = 0.0;
    for (u, v, w) in g.edges() {
        let su = g.strength(u);
        let sv = g.strength(v);
        if su > 0.0 && sv > 0.0 {
            cross += (w * w) / (su * sv);
            self_term += 0.5 * w * w * (1.0 / (su * su) + 1.0 / (sv * sv));
        }
    }
    1.0 - 1.0 / n - (cross + self_term) / (n * n)
}

/// Exact entropy of the symmetric normalized Laplacian scaled to unit trace —
/// the "what NL approximates" reference, used in tests and ablations. O(n³).
pub fn vnge_nl_exact(g: &Graph) -> f64 {
    if g.num_edges() == 0 {
        return 0.0;
    }
    let m = SymMatrix::laplacian_sym_normalized(g);
    let tr = m.trace();
    if tr <= 0.0 {
        return 0.0;
    }
    let eigs: Vec<f64> = m.eigenvalues().into_iter().map(|l| l / tr).collect();
    crate::entropy::entropy_from_eigenvalues(&eigs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;
    use crate::generators;
    use crate::util::Pcg64;

    #[test]
    fn nl_unweighted_matches_published_form() {
        // star S_4: hub degree 3, leaves 1; edges hub-leaf: 1/(3·1) each
        let g = generators::star(4);
        let n = 4.0;
        let expected = 1.0 - 1.0 / n - (3.0 * (1.0 / 3.0)) / (n * n);
        assert!((vnge_nl(&g) - expected).abs() < 1e-12);
    }

    #[test]
    fn nl_in_unit_range() {
        let mut rng = Pcg64::new(1);
        for seed in 0..5 {
            let mut r = Pcg64::new(seed);
            let g = generators::erdos_renyi(50, 0.1, &mut r);
            let v = vnge_nl(&g);
            assert!((0.0..=1.0).contains(&v), "v={v}");
            let _ = &mut rng;
        }
    }

    #[test]
    fn gl_le_nl_shape() {
        // the extra positive self term makes GL ≤ NL on the same graph
        let mut rng = Pcg64::new(2);
        let g = generators::barabasi_albert(80, 3, &mut rng);
        assert!(vnge_gl(&g) <= vnge_nl(&g));
    }

    #[test]
    fn empty_graph_zero() {
        let g = crate::graph::Graph::new(4);
        assert_bits_eq!(vnge_nl(&g), 0.0);
        assert_bits_eq!(vnge_gl(&g), 0.0);
        assert_bits_eq!(vnge_nl_exact(&g), 0.0);
    }

    #[test]
    fn nl_sensitive_to_weights() {
        let g1 = crate::graph::Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
        let g2 = crate::graph::Graph::from_edges(4, &[(0, 1, 5.0), (1, 2, 1.0), (2, 3, 1.0)]);
        assert!((vnge_nl(&g1) - vnge_nl(&g2)).abs() > 1e-6);
    }

    #[test]
    fn nl_exact_bounded_by_ln_n() {
        let mut rng = Pcg64::new(3);
        let g = generators::erdos_renyi(30, 0.2, &mut rng);
        let h = vnge_nl_exact(&g);
        assert!(h >= 0.0 && h <= 30f64.ln() + 1e-9, "h={h}");
    }

    #[test]
    fn approximations_track_density_direction() {
        // both heuristics should rise with graph regularity/density like Q
        let mut rng = Pcg64::new(4);
        let sparse = generators::erdos_renyi_avg_degree(100, 4.0, &mut rng);
        let dense = generators::erdos_renyi_avg_degree(100, 40.0, &mut rng);
        assert!(vnge_nl(&dense) > vnge_nl(&sparse));
        assert!(vnge_gl(&dense) > vnge_gl(&sparse));
    }
}
