//! Von Neumann graph entropy (VNGE): the exact O(n³) definition, the paper's
//! two linear-time FINGER approximations (Ĥ, H̃), the O(Δn+Δm) incremental
//! state (Theorem 2), and the two heuristic baselines (VNGE-NL, VNGE-GL).

pub mod baselines;
pub mod incremental;

pub use incremental::{FingerState, Scratch, SmaxPolicy};

use crate::graph::{Csr, Graph};
use crate::linalg::{power_iteration, PowerOpts, SymMatrix};

/// Shannon entropy of an eigenspectrum: −Σ λᵢ ln λᵢ with the 0·ln0 = 0
/// convention. Negative eigenvalues within −tol are clamped (numerical noise
/// from the eigensolver); anything below that is a caller bug.
pub fn entropy_from_eigenvalues(eigs: &[f64]) -> f64 {
    const TOL: f64 = 1e-12;
    let mut h = 0.0;
    for &l in eigs {
        debug_assert!(l > -1e-8, "significantly negative eigenvalue {l}");
        if l > TOL {
            h -= l * l.ln();
        }
    }
    h
}

/// Exact VNGE `H(G) = −Σ λᵢ ln λᵢ` over the eigenspectrum of
/// L_N = L/trace(L). O(n³) via the dense eigensolver — this is the baseline
/// FINGER's CTRR is measured against. Returns 0 for edgeless graphs.
pub fn exact_vnge(g: &Graph) -> f64 {
    if g.total_weight() <= 0.0 {
        return 0.0;
    }
    let eigs = SymMatrix::laplacian_normalized(g).eigenvalues();
    entropy_from_eigenvalues(&eigs)
}

/// The quadratic proxy Q of Lemma 1:
/// `Q = 1 − c²(Σᵢ sᵢ² + 2·Σ_{(i,j)∈E} wᵢⱼ²)`, c = 1/trace(L). O(n+m).
/// Equals `1 − Σ λᵢ²` exactly (an identity, not an approximation).
pub fn quadratic_q(g: &Graph) -> f64 {
    let s = g.total_weight();
    if s <= 0.0 {
        return 0.0;
    }
    let c = 1.0 / s;
    let (s2, w2) = g.q_moments();
    1.0 - c * c * (s2 + 2.0 * w2)
}

/// FINGER-Ĥ (Eq. 1): `Ĥ = −Q·ln λ_max`, λ_max via power iteration on the CSR
/// view. O(n+m). Lower-bounds H for λ_max < 1 (any graph with a ≥3-node
/// connected component).
pub fn finger_hhat(g: &Graph) -> f64 {
    finger_hhat_opts(g, &PowerOpts::default())
}

/// FINGER-Ĥ with explicit power-iteration options.
pub fn finger_hhat_opts(g: &Graph, opts: &PowerOpts) -> f64 {
    if g.total_weight() <= 0.0 {
        return 0.0;
    }
    let q = quadratic_q(g);
    let lam = power_iteration(&Csr::from_graph(g), opts);
    hhat_from_parts(q, lam)
}

/// Ĥ from precomputed parts (used by the XLA offload path too).
pub fn hhat_from_parts(q: f64, lambda_max: f64) -> f64 {
    if lambda_max <= 0.0 {
        return 0.0;
    }
    // λ_max ≤ 1 by trace normalization; ln(λ_max) ≤ 0 and Q ≥ 0.
    (-q * lambda_max.ln()).max(0.0)
}

/// FINGER-H̃ (Eq. 2): `H̃ = −Q·ln(2c·s_max)` — replaces λ_max by the
/// Anderson–Morley bound, enabling the O(Δ) incremental update. O(n+m) from
/// scratch. Satisfies H̃ ≤ Ĥ ≤ H.
pub fn finger_htilde(g: &Graph) -> f64 {
    if g.total_weight() <= 0.0 {
        return 0.0;
    }
    let q = quadratic_q(g);
    let c = 1.0 / g.total_weight();
    htilde_from_parts(q, c, g.s_max())
}

/// H̃ from precomputed parts (Q, c, s_max) — the incremental state's formula.
pub fn htilde_from_parts(q: f64, c: f64, s_max: f64) -> f64 {
    let arg = 2.0 * c * s_max;
    if arg <= 0.0 {
        return 0.0;
    }
    // 2c·s_max ≥ λ_max can exceed 1 on K_2-like graphs (λ_max = 1 exactly);
    // clamp so the entropy surrogate stays nonnegative.
    let arg = arg.min(1.0);
    (-q * arg.ln()).max(0.0)
}

/// Theorem 1 bounds on H given Q and the extreme positive eigenvalues of L_N:
/// `−Q·ln(λ_max)/(1−λ_min) ≤ H ≤ −Q·ln(λ_min)/(1−λ_max)` (requires λ_max<1).
pub fn theorem1_bounds(q: f64, lambda_min: f64, lambda_max: f64) -> Option<(f64, f64)> {
    if !(0.0 < lambda_min && lambda_min <= lambda_max && lambda_max < 1.0) {
        return None;
    }
    let lower = -q * lambda_max.ln() / (1.0 - lambda_min);
    let upper = -q * lambda_min.ln() / (1.0 - lambda_max);
    Some((lower, upper))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;
    use crate::generators;
    use crate::util::Pcg64;

    #[test]
    fn entropy_of_uniform_spectrum() {
        // k equal eigenvalues 1/k -> ln k
        let eigs = vec![0.25; 4];
        assert!((entropy_from_eigenvalues(&eigs) - 4f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn entropy_ignores_zeros() {
        let eigs = vec![0.5, 0.5, 0.0, 0.0];
        assert!((entropy_from_eigenvalues(&eigs) - 2f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn complete_graph_exact_equals_ln_n_minus_1() {
        // Theorem 1 equality case: H(K_n) = ln(n−1)
        for n in [4, 8, 16] {
            let g = generators::complete(n, 1.0);
            let h = exact_vnge(&g);
            assert!((h - ((n - 1) as f64).ln()).abs() < 1e-9, "n={n} h={h}");
        }
    }

    #[test]
    fn complete_graph_weighted_invariant() {
        // identical edge weight x doesn't change H (trace normalization)
        let h1 = exact_vnge(&generators::complete(10, 1.0));
        let h2 = exact_vnge(&generators::complete(10, 3.7));
        assert!((h1 - h2).abs() < 1e-9);
    }

    #[test]
    fn q_matches_eigen_identity() {
        // Q = 1 − Σλ² exactly (eq. S1)
        let mut rng = Pcg64::new(5);
        let g = generators::erdos_renyi(60, 0.1, &mut rng);
        let q = quadratic_q(&g);
        let eigs = SymMatrix::laplacian_normalized(&g).eigenvalues();
        let q_eig = 1.0 - eigs.iter().map(|l| l * l).sum::<f64>();
        assert!((q - q_eig).abs() < 1e-9, "{q} vs {q_eig}");
    }

    #[test]
    fn ordering_htilde_le_hhat_le_h() {
        for seed in 0..6 {
            let mut rng = Pcg64::new(seed);
            let g = generators::erdos_renyi(80, 0.08, &mut rng);
            if g.num_edges() < 3 {
                continue;
            }
            let h = exact_vnge(&g);
            let hhat = finger_hhat(&g);
            let htil = finger_htilde(&g);
            assert!(htil <= hhat + 1e-9, "seed={seed}: {htil} > {hhat}");
            assert!(hhat <= h + 1e-6, "seed={seed}: {hhat} > {h}");
        }
    }

    #[test]
    fn single_edge_graph_zero_entropy() {
        // K_2: spectrum of L_N is {0, 1} -> H = 0; Q = 0 so Ĥ = H̃ = 0 too
        let g = Graph::from_edges(2, &[(0, 1, 3.0)]);
        assert!(exact_vnge(&g).abs() < 1e-12);
        assert!(finger_hhat(&g).abs() < 1e-12);
        assert!(finger_htilde(&g).abs() < 1e-12);
    }

    #[test]
    fn empty_graph_zero() {
        let g = Graph::new(5);
        assert_bits_eq!(exact_vnge(&g), 0.0);
        assert_bits_eq!(finger_hhat(&g), 0.0);
        assert_bits_eq!(finger_htilde(&g), 0.0);
        assert_bits_eq!(quadratic_q(&g), 0.0);
    }

    #[test]
    fn theorem1_bounds_contain_h() {
        let mut rng = Pcg64::new(17);
        let g = generators::erdos_renyi(50, 0.15, &mut rng);
        let h = exact_vnge(&g);
        let q = quadratic_q(&g);
        let eigs = SymMatrix::laplacian_normalized(&g).eigenvalues();
        let pos: Vec<f64> = eigs.iter().copied().filter(|&l| l > 1e-10).collect();
        let (lmin, lmax) = (pos[0], *pos.last().unwrap());
        let (lo, hi) = theorem1_bounds(q, lmin, lmax).unwrap();
        assert!(lo <= h + 1e-9 && h <= hi + 1e-9, "{lo} <= {h} <= {hi}");
    }

    #[test]
    fn theorem1_rejects_degenerate() {
        assert!(theorem1_bounds(0.5, 0.0, 0.5).is_none());
        assert!(theorem1_bounds(0.5, 0.2, 1.0).is_none());
        assert!(theorem1_bounds(0.5, 0.6, 0.5).is_none());
    }

    #[test]
    fn h_upper_bound_ln_n_minus_1() {
        // H(G) ≤ ln(n−1) for any G (Passerini–Severini)
        for seed in 0..4 {
            let mut rng = Pcg64::new(seed + 100);
            let g = generators::barabasi_albert(60, 3, &mut rng);
            assert!(exact_vnge(&g) <= (59f64).ln() + 1e-9);
        }
    }

    #[test]
    fn approximation_error_decays_with_density() {
        // Fig 1 behaviour: AE = H − Ĥ shrinks as average degree grows
        let mut rng = Pcg64::new(23);
        let sparse = generators::erdos_renyi_avg_degree(150, 4.0, &mut rng);
        let dense = generators::erdos_renyi_avg_degree(150, 60.0, &mut rng);
        let ae_sparse = exact_vnge(&sparse) - finger_hhat(&sparse);
        let ae_dense = exact_vnge(&dense) - finger_hhat(&dense);
        assert!(ae_dense < ae_sparse, "{ae_dense} !< {ae_sparse}");
    }

    #[test]
    fn hhat_from_parts_clamps() {
        assert_bits_eq!(hhat_from_parts(0.5, 0.0), 0.0);
        assert_bits_eq!(hhat_from_parts(-1e-18, 0.5), 0.0); // tiny negative Q noise
    }
}
