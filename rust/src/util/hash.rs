//! Deterministic hashing (FxHash-style). std's default `RandomState` salts
//! per process, which makes adjacency-map iteration order — and therefore
//! every seeded experiment that consumes RNG draws while iterating edges —
//! irreproducible across runs. All graph-internal maps use this instead.

use std::hash::{BuildHasherDefault, Hasher};

/// The Firefox/rustc multiply-rotate hasher; deterministic, fast for small
/// integer keys (our node ids).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// Deterministic hash map / set aliases.
pub type DetHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FxHasher>>;
pub type DetHashSet<K> = std::collections::HashSet<K, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut m1: DetHashMap<u32, u32> = DetHashMap::default();
        let mut m2: DetHashMap<u32, u32> = DetHashMap::default();
        for k in 0..1000u32 {
            m1.insert(k * 7, k);
            m2.insert(k * 7, k);
        }
        let o1: Vec<_> = m1.iter().collect();
        let o2: Vec<_> = m2.iter().collect();
        assert_eq!(o1, o2, "iteration order must be deterministic");
    }

    #[test]
    fn hashes_differ_for_different_keys() {
        use std::hash::Hash;
        let h = |x: u32| {
            let mut hasher = FxHasher::default();
            x.hash(&mut hasher);
            hasher.finish()
        };
        assert_ne!(h(1), h(2));
        assert_eq!(h(42), h(42));
    }
}
