//! Plain-text table rendering for experiment reports (the benches print
//! paper-style rows; no external table crate available offline).

/// A simple left-padded text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let empty = String::new();
                let c = cells.get(i).unwrap_or(&empty);
                line.push_str(&format!(" {:<width$} |", c, width = w));
            }
            line
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            out.push('\n');
            let mut sep = String::from("|");
            for w in &widths {
                sep.push_str(&"-".repeat(w + 2));
                sep.push('|');
            }
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Format a ratio as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("| name      | value |"), "{s}");
        assert!(s.contains("| long-name | 2.5   |"), "{s}");
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn table_handles_ragged_rows() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x".into(), "extra".into()]);
        let s = t.render();
        assert!(s.contains("extra"));
    }

    #[test]
    fn secs_units() {
        // finger-lint: allow(FL003): compares formatted strings; literal float args only
        assert_eq!(secs(0.0000005), "0.5µs");
        // finger-lint: allow(FL003): compares formatted strings; literal float args only
        assert_eq!(secs(0.002), "2.00ms");
        // finger-lint: allow(FL003): compares formatted strings; literal float args only
        assert_eq!(secs(2.0), "2.000s");
    }

    #[test]
    fn pct_format() {
        // finger-lint: allow(FL003): compares formatted strings; literal float args only
        assert_eq!(pct(0.975), "97.5%");
    }
}
