//! Foundation utilities built in-tree (the offline registry only carries the
//! `xla` crate closure, so PRNG, statistics, timing, table formatting and the
//! property-testing harness are all implemented here).

pub mod fmt;
pub mod hash;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg64;
pub use timer::Timer;
