//! Foundation utilities built in-tree (the offline registry only carries the
//! `xla` crate closure, so PRNG, statistics, timing, table formatting and the
//! property-testing harness are all implemented here).

pub mod fmt;
pub mod hash;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg64;
pub use timer::Timer;

/// Bit-exact float equality assertion: the approved way to compare scores in
/// tests (lint rule FL003 flags raw `assert_eq!` on float expressions; raw
/// `==` rounds through the comparison semantics of NaN and signed zero,
/// while the repo's identity guarantees are stated bit-for-bit — see
/// docs/LINTS.md). Both sides are evaluated once and compared via
/// `f64::to_bits`.
#[macro_export]
macro_rules! assert_bits_eq {
    ($a:expr, $b:expr $(,)?) => {{
        let (a, b): (f64, f64) = ($a, $b);
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "assert_bits_eq failed: {a:?} ({:#018x}) vs {b:?} ({:#018x})",
            a.to_bits(),
            b.to_bits()
        );
    }};
    ($a:expr, $b:expr, $($msg:tt)+) => {{
        let (a, b): (f64, f64) = ($a, $b);
        assert_eq!(a.to_bits(), b.to_bits(), $($msg)+);
    }};
}
