//! Statistics used by the evaluation harness: summary moments, Pearson and
//! Spearman correlation (the paper reports PCC in Table 2 and SRCC in
//! Table S1), fractional ranking with tie handling, the log-bucket
//! [`Histogram`] shared by the load driver and the observability layer
//! (`crate::obs` mirrors its bucket math with atomic cells), and the
//! [`LatencySummary`] rendering helper every latency report goes through.

/// Arithmetic mean. Returns 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance. Returns 0.0 on inputs shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Pearson correlation coefficient. Returns 0.0 when either side is
/// degenerate (constant) or lengths mismatch — callers treat "no linear
/// relationship measurable" as zero correlation, matching how the paper's
/// tables would render a flat metric.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    if xs.len() != ys.len() || xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx2 = 0.0;
    let mut dy2 = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        num += dx * dy;
        dx2 += dx * dx;
        dy2 += dy * dy;
    }
    if dx2 <= 0.0 || dy2 <= 0.0 {
        return 0.0;
    }
    num / (dx2.sqrt() * dy2.sqrt())
}

/// Fractional ranks (1-based, ties get the average of their positions),
/// the standard ranking for Spearman's rho.
pub fn fractional_ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        // positions i..=j (0-based) share the average rank.
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg;
        }
        i = j + 1;
    }
    ranks
}

/// Spearman's rank correlation coefficient (Pearson on fractional ranks,
/// which handles ties correctly).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    if xs.len() != ys.len() || xs.len() < 2 {
        return 0.0;
    }
    pearson(&fractional_ranks(xs), &fractional_ranks(ys))
}

/// Indices of the top-k largest values, descending. Ties broken by index.
pub fn top_k_indices(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Percentile (nearest-rank) of a sample; p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize - 1;
    v[rank.min(v.len() - 1)]
}

/// Bucket count for [`Histogram`]: 16 exact buckets below 16, then 16
/// log-spaced sub-buckets per power of two up to `u64::MAX`. Public so the
/// atomic mirror in `crate::obs` and the wire encoding of histograms can
/// share the exact same table shape.
pub const HIST_BUCKETS: usize = 976;

/// Bucket index a value lands in: exact below 16, then 16 log-spaced
/// sub-buckets per power of two (1/16 relative error bound). This is *the*
/// bucket function — [`Histogram`], the atomic recorders in `crate::obs`,
/// and the `METRICS` wire encoding all index with it, so bucket counts can
/// travel between them unchanged.
pub fn bucket_index(v: u64) -> usize {
    if v < 16 {
        return v as usize;
    }
    let msb = 63 - u64::from(v.leading_zeros()); // >= 4 since v >= 16
    ((msb - 3) * 16 + ((v >> (msb - 4)) & 15)) as usize
}

/// The largest value bucket `idx` covers — quantiles report this upper
/// edge, so they never under-estimate a latency.
pub fn bucket_upper(idx: usize) -> u64 {
    if idx < 32 {
        // buckets 0..32 are exact (values 0..16 unit-wide, 16..32 too)
        return idx as u64;
    }
    let msb = (idx / 16) as u32 + 3;
    let sub = (idx % 16) as u128;
    // u128 arithmetic: the very top bucket's edge would overflow u64
    let upper = (1u128 << msb) + ((sub + 1) << (msb - 4)) - 1;
    upper.min(u64::MAX as u128) as u64
}

/// A dependency-free fixed-bucket latency histogram (HDR-style).
///
/// Values below 16 land in exact unit buckets; above that, each power of
/// two is split into 16 sub-buckets, bounding the relative quantile error
/// at 1/16 (≈6%) while the whole table stays under 8 KiB — mergeable
/// across load-driver worker threads without locks, O(1) `record`, and no
/// per-sample allocation. Units are the caller's (the load driver records
/// per-event round-trip microseconds).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; HIST_BUCKETS], count: 0 }
    }

    pub fn record(&mut self, v: u64) {
        let idx = bucket_index(v);
        if let Some(c) = self.counts.get_mut(idx) {
            *c += 1;
            self.count += 1;
        }
    }

    /// Add `n` samples directly into bucket `idx` (out-of-range indices are
    /// ignored). This is how bucket counts re-enter a `Histogram` after
    /// traveling through the `METRICS` wire encoding or an atomic recorder
    /// snapshot — both index with [`bucket_index`], so counts transfer
    /// without re-bucketing error.
    pub fn add_count(&mut self, idx: usize, n: u64) {
        if let Some(c) = self.counts.get_mut(idx) {
            *c += n;
            self.count += n;
        }
    }

    /// Fold another histogram in (per-worker histograms merge at the end).
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The non-empty buckets as `(index, count)`, ascending by index — the
    /// sparse form the wire encoding and JSON snapshots ship.
    pub fn nonzero_buckets(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts.iter().enumerate().filter(|(_, &c)| c > 0).map(|(i, &c)| (i, c))
    }

    /// Nearest-rank percentile, `p` in [0, 100]. Returns the covering
    /// bucket's upper edge (within 1/16 relative error above the true
    /// value); 0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (((p / 100.0) * self.count as f64).ceil().max(1.0) as u64).min(self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(HIST_BUCKETS - 1)
    }
}

/// One latency distribution rendered to the numbers every report in this
/// repo shows: count, mean, p50 and p99. The single summary/display path
/// shared by the bench harness (`Bencher::run` summarizes its second-valued
/// samples with it), the load driver (`net::traffic` summarizes its
/// microsecond [`Histogram`]), and the observability snapshots
/// (`crate::obs` renders every atomic histogram through it) — so a p99
/// means the same thing everywhere it is printed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    /// Mean in the samples' unit (exact for `from_samples`; bucket-edge
    /// approximation within the 1/16 bound for `from_histogram`).
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
}

impl LatencySummary {
    /// Summarize raw samples (nearest-rank percentiles, exact mean).
    pub fn from_samples(xs: &[f64]) -> Self {
        Self {
            count: xs.len() as u64,
            mean: mean(xs),
            p50: percentile(xs, 50.0),
            p99: percentile(xs, 99.0),
        }
    }

    /// Summarize a [`Histogram`] (mean approximated from bucket upper edges,
    /// so like the percentiles it never under-estimates by more than the
    /// bucket error bound).
    pub fn from_histogram(h: &Histogram) -> Self {
        let count = h.count();
        let mean = if count == 0 {
            0.0
        } else {
            let total: f64 =
                h.nonzero_buckets().map(|(i, c)| bucket_upper(i) as f64 * c as f64).sum();
            total / count as f64
        };
        Self { count, mean, p50: h.percentile(50.0) as f64, p99: h.percentile(99.0) as f64 }
    }

    /// Render with the shared seconds formatter (`mean=… p50=… p99=…`) —
    /// the bench report form.
    pub fn report_secs(&self) -> String {
        format!(
            "mean={:<10} p50={:<10} p99={}",
            crate::util::fmt::secs(self.mean),
            crate::util::fmt::secs(self.p50),
            crate::util::fmt::secs(self.p99),
        )
    }

    /// Render integral-unit summaries (microsecond histograms) compactly:
    /// `n=… mean=… p50=… p99=…`.
    pub fn report_units(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.0}{unit} p50={:.0}{unit} p99={:.0}{unit}",
            self.count, self.mean, self.p50, self.p99
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;
    use crate::util::proptest;
    use crate::util::Pcg64;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!(close(mean(&xs), 2.5));
        assert!(close(variance(&xs), 1.25));
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_bits_eq!(mean(&[]), 0.0);
        assert_bits_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn pearson_perfect_positive() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [10.0, 20.0, 30.0];
        assert!(close(pearson(&xs, &ys), 1.0));
    }

    #[test]
    fn pearson_perfect_negative() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [3.0, 2.0, 1.0];
        assert!(close(pearson(&xs, &ys), -1.0));
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_bits_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn pearson_known_value() {
        // Hand-computed: Σdxdy = 15, Σdx² = 10, Σdy² = 22.8 ⇒ r = 15/√228
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [2.0, 3.0, 5.0, 6.0, 8.0];
        let r = pearson(&xs, &ys);
        assert!((r - 15.0 / 228f64.sqrt()).abs() < 1e-12, "r={r}");
    }

    #[test]
    fn ranks_no_ties() {
        let r = fractional_ranks(&[30.0, 10.0, 20.0]);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(r, vec![3.0, 1.0, 2.0]);
    }

    #[test]
    fn ranks_with_ties_average() {
        let r = fractional_ranks(&[10.0, 20.0, 20.0, 30.0]);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_monotone_nonlinear_is_one() {
        let xs = [1.0f64, 2.0, 3.0, 4.0, 5.0];
        let ys: Vec<f64> = xs.iter().map(|x| x.exp()).collect();
        assert!(close(spearman(&xs, &ys), 1.0));
    }

    #[test]
    fn spearman_reversed_is_minus_one() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [9.0, 7.0, 5.0, 1.0];
        assert!(close(spearman(&xs, &ys), -1.0));
    }

    #[test]
    fn spearman_ties_known() {
        // xs ranks: [1.5, 1.5, 3, 4]; ys ranks: [1, 2, 3, 4]
        let xs = [5.0, 5.0, 7.0, 9.0];
        let ys = [1.0, 2.0, 3.0, 4.0];
        let rho = spearman(&xs, &ys);
        assert!((rho - 0.9486832980505138).abs() < 1e-12, "rho={rho}");
    }

    #[test]
    fn top_k_descending() {
        let xs = [0.1, 5.0, 3.0, 4.0];
        assert_eq!(top_k_indices(&xs, 2), vec![1, 3]);
    }

    #[test]
    fn top_k_truncates_at_len() {
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(top_k_indices(&[1.0], 5), vec![0]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!(close(percentile(&xs, 50.0), 3.0));
        assert!(close(percentile(&xs, 100.0), 5.0));
        assert!(close(percentile(&xs, 1.0), 1.0));
    }

    #[test]
    fn histogram_is_exact_below_sixteen() {
        let mut h = Histogram::new();
        for v in 0..16 {
            h.record(v);
        }
        assert_eq!(h.count(), 16);
        assert_eq!(h.percentile(100.0), 15);
        assert_eq!(h.percentile(0.0), 0);
        let mut one = Histogram::new();
        one.record(7);
        assert_eq!(one.percentile(50.0), 7);
    }

    #[test]
    fn histogram_bounds_relative_error_at_one_sixteenth() {
        for v in [16u64, 17, 100, 999, 1_000, 65_536, 1_000_000, u64::MAX / 3] {
            let mut h = Histogram::new();
            h.record(v);
            let got = h.percentile(99.0);
            assert!(got >= v, "p99 {got} under-estimates {v}");
            assert!(got - v <= v / 16, "p99 {got} off by more than 1/16 from {v}");
        }
    }

    #[test]
    fn histogram_quantiles_over_a_uniform_ramp() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!((500..=540).contains(&p50), "p50={p50}");
        assert!((990..=1055).contains(&p99), "p99={p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn histogram_merge_matches_recording_everything_in_one() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for v in [3u64, 900, 12_345, 70, 70, 8_000_000] {
            whole.record(v);
        }
        for v in [3u64, 900, 12_345] {
            a.record(v);
        }
        for v in [70u64, 70, 8_000_000] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        for p in [0.0, 25.0, 50.0, 75.0, 99.0, 100.0] {
            assert_eq!(a.percentile(p), whole.percentile(p), "p={p}");
        }
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(99.0), 0);
    }

    #[test]
    fn histogram_sparse_roundtrip_via_add_count() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 15, 16, 17, 999, 1_000_000, u64::MAX] {
            h.record(v);
        }
        let mut back = Histogram::new();
        for (idx, n) in h.nonzero_buckets() {
            back.add_count(idx, n);
        }
        assert_eq!(back, h, "sparse form must reconstruct the exact histogram");
        // out-of-range indices are ignored, not panicking
        back.add_count(HIST_BUCKETS + 10, 3);
        assert_eq!(back, h);
    }

    #[test]
    fn summary_from_samples_and_histogram_agree_on_exact_buckets() {
        // values below 16 are bucketed exactly, so the two constructors
        // must agree exactly there
        let vals = [2u64, 4, 4, 8, 15];
        let xs: Vec<f64> = vals.iter().map(|&v| v as f64).collect();
        let mut h = Histogram::new();
        for &v in &vals {
            h.record(v);
        }
        let a = LatencySummary::from_samples(&xs);
        let b = LatencySummary::from_histogram(&h);
        assert_eq!(a.count, b.count);
        assert_bits_eq!(a.p50, b.p50);
        assert_bits_eq!(a.p99, b.p99);
        assert!(close(a.mean, b.mean));
        assert!(b.report_units("us").contains("p99="));
        assert!(a.report_secs().contains("p50="));
    }

    /// Strategy for the histogram property tests: a few hundred values
    /// spread across the full bucket range (unit, mid, huge).
    fn value_vec(rng: &mut Pcg64, size: usize) -> Vec<u64> {
        let n = 1 + rng.below(size.max(1) + 8);
        (0..n)
            .map(|_| {
                let shift = rng.below(64) as u32;
                rng.below(u32::MAX as usize + 1) as u64 >> (shift % 33) << (shift % 31)
            })
            .collect()
    }

    fn hist_of(vals: &[u64]) -> Histogram {
        let mut h = Histogram::new();
        for &v in vals {
            h.record(v);
        }
        h
    }

    #[test]
    fn prop_merge_is_commutative_and_associative() {
        proptest::check(
            |rng: &mut Pcg64, size: usize| {
                (value_vec(rng, size), value_vec(rng, size), value_vec(rng, size))
            },
            |(xs, ys, zs)| {
                let (hx, hy, hz) = (hist_of(xs), hist_of(ys), hist_of(zs));
                // commutative: x + y == y + x
                let mut xy = hx.clone();
                xy.merge(&hy);
                let mut yx = hy.clone();
                yx.merge(&hx);
                crate::prop_assert!(xy == yx, "merge not commutative");
                // associative: (x + y) + z == x + (y + z)
                let mut xy_z = xy.clone();
                xy_z.merge(&hz);
                let mut yz = hy.clone();
                yz.merge(&hz);
                let mut x_yz = hx.clone();
                x_yz.merge(&yz);
                crate::prop_assert!(xy_z == x_yz, "merge not associative");
                Ok(())
            },
        );
    }

    #[test]
    fn prop_empty_merge_is_identity() {
        proptest::check(value_vec, |xs| {
            let h = hist_of(xs);
            let mut merged = h.clone();
            merged.merge(&Histogram::new());
            crate::prop_assert!(merged == h, "merging an empty histogram changed it");
            let mut from_empty = Histogram::new();
            from_empty.merge(&h);
            crate::prop_assert!(from_empty == h, "merging into an empty histogram lost data");
            Ok(())
        });
    }

    #[test]
    fn prop_quantiles_monotone_in_q() {
        proptest::check(value_vec, |xs| {
            let h = hist_of(xs);
            let mut prev = h.percentile(0.0);
            for q in 1..=100u32 {
                let cur = h.percentile(q as f64);
                crate::prop_assert!(cur >= prev, "p{q}={cur} < p{}={prev}", q - 1);
                prev = cur;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_recorded_values_respect_bucket_error_bound() {
        proptest::check(value_vec, |xs| {
            for &v in xs {
                let mut h = Histogram::new();
                h.record(v);
                // p100 of a single sample is its bucket's upper edge: never
                // below the true value, and within 1/16 relative error
                let got = h.percentile(100.0);
                crate::prop_assert!(got >= v, "bucket edge {got} under-estimates {v}");
                crate::prop_assert!(
                    got - v <= v / 16,
                    "bucket edge {got} exceeds the 1/16 bound for {v}"
                );
                // and the edge is consistent with the shared bucket fns
                crate::prop_assert!(
                    got == bucket_upper(bucket_index(v)),
                    "percentile edge disagrees with bucket_upper(bucket_index)"
                );
            }
            Ok(())
        });
    }
}
