//! Statistics used by the evaluation harness: summary moments, Pearson and
//! Spearman correlation (the paper reports PCC in Table 2 and SRCC in
//! Table S1), and fractional ranking with tie handling.

/// Arithmetic mean. Returns 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance. Returns 0.0 on inputs shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Pearson correlation coefficient. Returns 0.0 when either side is
/// degenerate (constant) or lengths mismatch — callers treat "no linear
/// relationship measurable" as zero correlation, matching how the paper's
/// tables would render a flat metric.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    if xs.len() != ys.len() || xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx2 = 0.0;
    let mut dy2 = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        num += dx * dy;
        dx2 += dx * dx;
        dy2 += dy * dy;
    }
    if dx2 <= 0.0 || dy2 <= 0.0 {
        return 0.0;
    }
    num / (dx2.sqrt() * dy2.sqrt())
}

/// Fractional ranks (1-based, ties get the average of their positions),
/// the standard ranking for Spearman's rho.
pub fn fractional_ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        // positions i..=j (0-based) share the average rank.
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg;
        }
        i = j + 1;
    }
    ranks
}

/// Spearman's rank correlation coefficient (Pearson on fractional ranks,
/// which handles ties correctly).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    if xs.len() != ys.len() || xs.len() < 2 {
        return 0.0;
    }
    pearson(&fractional_ranks(xs), &fractional_ranks(ys))
}

/// Indices of the top-k largest values, descending. Ties broken by index.
pub fn top_k_indices(xs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Percentile (nearest-rank) of a sample; p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize - 1;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!(close(mean(&xs), 2.5));
        assert!(close(variance(&xs), 1.25));
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_bits_eq!(mean(&[]), 0.0);
        assert_bits_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn pearson_perfect_positive() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [10.0, 20.0, 30.0];
        assert!(close(pearson(&xs, &ys), 1.0));
    }

    #[test]
    fn pearson_perfect_negative() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [3.0, 2.0, 1.0];
        assert!(close(pearson(&xs, &ys), -1.0));
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_bits_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn pearson_known_value() {
        // Hand-computed: Σdxdy = 15, Σdx² = 10, Σdy² = 22.8 ⇒ r = 15/√228
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [2.0, 3.0, 5.0, 6.0, 8.0];
        let r = pearson(&xs, &ys);
        assert!((r - 15.0 / 228f64.sqrt()).abs() < 1e-12, "r={r}");
    }

    #[test]
    fn ranks_no_ties() {
        let r = fractional_ranks(&[30.0, 10.0, 20.0]);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(r, vec![3.0, 1.0, 2.0]);
    }

    #[test]
    fn ranks_with_ties_average() {
        let r = fractional_ranks(&[10.0, 20.0, 20.0, 30.0]);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn spearman_monotone_nonlinear_is_one() {
        let xs = [1.0f64, 2.0, 3.0, 4.0, 5.0];
        let ys: Vec<f64> = xs.iter().map(|x| x.exp()).collect();
        assert!(close(spearman(&xs, &ys), 1.0));
    }

    #[test]
    fn spearman_reversed_is_minus_one() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [9.0, 7.0, 5.0, 1.0];
        assert!(close(spearman(&xs, &ys), -1.0));
    }

    #[test]
    fn spearman_ties_known() {
        // xs ranks: [1.5, 1.5, 3, 4]; ys ranks: [1, 2, 3, 4]
        let xs = [5.0, 5.0, 7.0, 9.0];
        let ys = [1.0, 2.0, 3.0, 4.0];
        let rho = spearman(&xs, &ys);
        assert!((rho - 0.9486832980505138).abs() < 1e-12, "rho={rho}");
    }

    #[test]
    fn top_k_descending() {
        let xs = [0.1, 5.0, 3.0, 4.0];
        assert_eq!(top_k_indices(&xs, 2), vec![1, 3]);
    }

    #[test]
    fn top_k_truncates_at_len() {
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(top_k_indices(&[1.0], 5), vec![0]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!(close(percentile(&xs, 50.0), 3.0));
        assert!(close(percentile(&xs, 100.0), 5.0));
        assert!(close(percentile(&xs, 1.0), 1.0));
    }
}
