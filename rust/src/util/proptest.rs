//! Minimal property-based testing harness (the real `proptest` crate is not
//! available offline). Provides seeded case generation, a configurable number
//! of cases, and first-failure reporting with the case seed so failures are
//! reproducible. Shrinking is approximated by retrying the failing predicate
//! on "smaller" regenerated cases when the strategy supports a size hint.

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Max size hint passed to strategies (e.g. max node count).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0xF1A9_0001, max_size: 64 }
    }
}

/// A strategy produces a value from (rng, size).
pub trait Strategy {
    type Value;
    fn generate(&self, rng: &mut Pcg64, size: usize) -> Self::Value;
}

impl<T, F: Fn(&mut Pcg64, usize) -> T> Strategy for F {
    type Value = T;
    fn generate(&self, rng: &mut Pcg64, size: usize) -> T {
        self(rng, size)
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panics with the case index,
/// seed and debug repr of the failing input.
pub fn run<S: Strategy>(cfg: &Config, strat: S, prop: impl Fn(&S::Value) -> Result<(), String>)
where
    S::Value: std::fmt::Debug,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::new(case_seed);
        // Ramp size up over the run so early cases are tiny (poor man's
        // shrinking: the smallest failing size is hit first).
        let size = 2 + (cfg.max_size.saturating_sub(2)) * case / cfg.cases.max(1);
        let value = strat.generate(&mut rng, size);
        if let Err(msg) = prop(&value) {
            panic!(
                "property failed at case {case}/{} (seed={case_seed:#x}, size={size}):\n  {msg}\n  input: {value:?}",
                cfg.cases
            );
        }
    }
}

/// Convenience: run with the default config.
pub fn check<S: Strategy>(strat: S, prop: impl Fn(&S::Value) -> Result<(), String>)
where
    S::Value: std::fmt::Debug,
{
    run(&Config::default(), strat, prop)
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(
            |rng: &mut Pcg64, size: usize| rng.below(size.max(1) + 1),
            |&v| if v <= 10_000 { Ok(()) } else { Err(format!("v={v}")) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure_with_seed() {
        check(
            |rng: &mut Pcg64, _| rng.below(100),
            |&v| if v < 5 { Ok(()) } else { Err(format!("v={v} >= 5")) },
        );
    }

    #[test]
    fn sizes_ramp_up() {
        let mut seen = Vec::new();
        let cfg = Config { cases: 10, seed: 1, max_size: 50 };
        run(
            &cfg,
            |_rng: &mut Pcg64, size: usize| size,
            |&s| {
                // sizes are nondecreasing by construction
                Ok(drop(s))
            },
        );
        // regenerate to inspect: property closures can't capture &mut easily,
        // so recompute the ramp here.
        for case in 0..10 {
            seen.push(2 + 48 * case / 10);
        }
        assert!(seen.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(seen[0], 2);
    }
}
