//! Wall-clock timing helpers for the CTRR (computation-time reduction ratio)
//! measurements and the bench harness.

use std::time::{Duration, Instant};

/// Simple start/elapsed timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Computation-time reduction ratio, the paper's CTRR:
/// `(Time(H) - Time(X)) / Time(H)`. Clamped to [-inf, 1]; returns 0 when the
/// baseline time is not positive.
pub fn ctrr(baseline_secs: f64, approx_secs: f64) -> f64 {
    if baseline_secs <= 0.0 {
        return 0.0;
    }
    (baseline_secs - approx_secs) / baseline_secs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn timer_measures_sleep() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(10));
        assert!(t.elapsed_secs() >= 0.009);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn ctrr_basic() {
        assert!((ctrr(10.0, 0.1) - 0.99).abs() < 1e-12);
        assert_bits_eq!(ctrr(0.0, 1.0), 0.0);
        assert!((ctrr(2.0, 2.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn restart_resets() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        let first = t.restart();
        assert!(first.as_secs_f64() >= 0.004);
        assert!(t.elapsed_secs() < first.as_secs_f64());
    }
}
