//! PCG64 (XSL-RR 128/64) pseudo-random number generator plus the handful of
//! distributions the generators and experiments need.
//!
//! Deterministic and seedable: every experiment in the repo threads an
//! explicit seed so tables/figures regenerate bit-identically.

/// PCG-XSL-RR 128/64. Constants from the PCG reference implementation.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id (distinct streams are
    /// statistically independent; used to give each pipeline worker its own).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability p.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate lambda.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -((1.0 - self.f64()).ln()) / lambda
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Weighted index sampling proportional to `weights` (linear scan; fine
    /// for the generator workloads that call it).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_small() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Pcg64::new(5);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Pcg64::new(13);
        let s = r.sample_distinct(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&x| x < 100));
    }

    #[test]
    fn sample_distinct_full() {
        let mut r = Pcg64::new(14);
        let mut s = r.sample_distinct(10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(15);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_prefers_heavy_index() {
        let mut r = Pcg64::new(17);
        let w = [1.0, 1.0, 98.0];
        let hits = (0..10_000).filter(|_| r.weighted(&w) == 2).count();
        assert!(hits > 9_500, "hits={hits}");
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::with_stream(42, 1);
        let mut b = Pcg64::with_stream(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
