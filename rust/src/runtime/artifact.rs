//! Artifact manifest: `artifacts/manifest.txt` lists every lowered HLO module
//! as `name n arity path` (one per line, `#` comments), written by
//! `python/compile/aot.py`.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled entry point at a fixed size.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Entry-point name (e.g. "hhat_dense").
    pub name: String,
    /// Matrix dimension n this module was lowered for.
    pub n: usize,
    /// Number of dense n×n inputs it takes.
    pub arity: usize,
    /// HLO text file, relative to the manifest directory.
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let s = line.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let mut it = s.split_whitespace();
            let name = it.next().context("name")?.to_string();
            let n: usize = it
                .next()
                .with_context(|| format!("line {}: n", lineno + 1))?
                .parse()
                .with_context(|| format!("line {}: bad n", lineno + 1))?;
            let arity: usize = it
                .next()
                .with_context(|| format!("line {}: arity", lineno + 1))?
                .parse()
                .with_context(|| format!("line {}: bad arity", lineno + 1))?;
            let rel = it.next().with_context(|| format!("line {}: path", lineno + 1))?;
            artifacts.push(Artifact { name, n, arity, path: dir.join(rel) });
        }
        Ok(Self { artifacts, dir })
    }

    /// Smallest artifact of `name` whose size fits a graph of `n` nodes.
    pub fn best_fit(&self, name: &str, n: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.name == name && a.n >= n)
            .min_by_key(|a| a.n)
    }

    /// All distinct sizes available for `name`.
    pub fn sizes(&self, name: &str) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.artifacts.iter().filter(|a| a.name == name).map(|a| a.n).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(lines: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("finger_manifest_{}", lines.len()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), lines).unwrap();
        dir
    }

    #[test]
    fn parses_lines() {
        let dir = write_manifest("# c\nhhat_dense 128 1 hhat_128.hlo.txt\nq_stats 64 1 q_64.hlo.txt\n");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].name, "hhat_dense");
        assert_eq!(m.artifacts[0].n, 128);
        assert_eq!(m.artifacts[0].arity, 1);
        assert!(m.artifacts[0].path.ends_with("hhat_128.hlo.txt"));
    }

    #[test]
    fn best_fit_picks_smallest_sufficient() {
        let dir =
            write_manifest("f 64 1 a\nf 128 1 b\nf 256 1 c\n");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.best_fit("f", 65).unwrap().n, 128);
        assert_eq!(m.best_fit("f", 64).unwrap().n, 64);
        assert!(m.best_fit("f", 500).is_none());
        assert!(m.best_fit("g", 1).is_none());
    }

    #[test]
    fn sizes_sorted() {
        let dir = write_manifest("f 256 1 a\nf 64 1 b\n");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.sizes("f"), vec![64, 256]);
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Manifest::load("/nonexistent/dir").is_err());
    }
}
