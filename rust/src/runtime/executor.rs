//! PJRT executor: compiles HLO-text artifacts once (cached) and executes them
//! with dense f32 inputs. Wraps the `xla` crate exactly as the reference
//! wiring in /opt/xla-example/load_hlo does: HLO **text** → HloModuleProto →
//! XlaComputation → PjRtLoadedExecutable.

use super::artifact::{Artifact, Manifest};
use anyhow::{ensure, Context, Result};
use std::collections::HashMap;

/// A loaded PJRT runtime with a compile cache keyed by (name, n).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: std::sync::Mutex<HashMap<(String, usize), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest from `dir`
    /// (typically `artifacts/`).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client, manifest, cache: std::sync::Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compiled(&self, art: &Artifact) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = (art.name.clone(), art.n);
        if let Some(exe) = self.cache.lock().expect("compile cache mutex poisoned").get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            art.path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", art.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compile {}", art.name))?,
        );
        self.cache.lock().expect("compile cache mutex poisoned").insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` (size-fitted to `n`) on dense row-major n×n
    /// f32 inputs (`inputs[k].len() == fit*fit`, already padded by the
    /// caller via `densify::padded_weights_f32`). Returns the scalar f32
    /// output. All L2 entry points return a single f32 scalar in a 1-tuple.
    pub fn run_scalar(&self, art: &Artifact, inputs: &[Vec<f32>]) -> Result<f64> {
        ensure!(inputs.len() == art.arity, "{} expects {} inputs", art.name, art.arity);
        let exe = self.compiled(art)?;
        let mut lits = Vec::with_capacity(inputs.len());
        for buf in inputs {
            ensure!(
                buf.len() == art.n * art.n,
                "input length {} != {}²",
                buf.len(),
                art.n
            );
            let lit = xla::Literal::vec1(buf).reshape(&[art.n as i64, art.n as i64])?;
            lits.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        ensure!(!values.is_empty(), "empty output from {}", art.name);
        Ok(values[0] as f64)
    }

    /// Look up the best-fitting artifact for (name, n).
    pub fn artifact(&self, name: &str, n: usize) -> Result<Artifact> {
        self.manifest
            .best_fit(name, n)
            .cloned()
            .with_context(|| format!("no artifact `{name}` fits n={n} (sizes: {:?})", self.manifest.sizes(name)))
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().expect("compile cache mutex poisoned").len()
    }
}

// Tests that need real artifacts live in rust/tests/runtime_integration.rs
// and skip gracefully when `make artifacts` hasn't been run.
