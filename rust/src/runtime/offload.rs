//! High-level offload API: compute FINGER quantities through the AOT XLA
//! artifacts (dense path) instead of the native sparse implementation. The
//! crossover ablation in `benches/perf_hotpath.rs` quantifies when this pays
//! off (dense contact-map workloads like Hi-C; never for very sparse graphs).

use super::densify::padded_weights_f32;
use super::executor::Runtime;
use crate::graph::Graph;
use anyhow::Result;

/// Entropy computations backed by the XLA runtime.
pub struct XlaEntropy<'a> {
    rt: &'a Runtime,
}

impl<'a> XlaEntropy<'a> {
    pub fn new(rt: &'a Runtime) -> Self {
        Self { rt }
    }

    fn run1(&self, name: &str, g: &Graph) -> Result<f64> {
        let art = self.rt.artifact(name, g.num_nodes())?;
        let w = padded_weights_f32(g, art.n)?;
        self.rt.run_scalar(&art, &[w])
    }

    /// Q via the L1 Pallas q-stats kernel.
    pub fn q(&self, g: &Graph) -> Result<f64> {
        self.run1("q_stats", g)
    }

    /// FINGER-Ĥ via the L2 dense graph (Q kernel + on-device power iteration).
    pub fn hhat(&self, g: &Graph) -> Result<f64> {
        self.run1("hhat_dense", g)
    }

    /// FINGER-JSdist (Fast) between two graphs via the L2 dense graph.
    pub fn jsdist(&self, a: &Graph, b: &Graph) -> Result<f64> {
        let n = a.num_nodes().max(b.num_nodes());
        let art = self.rt.artifact("jsdist_dense", n)?;
        let wa = padded_weights_f32(a, art.n)?;
        let wb = padded_weights_f32(b, art.n)?;
        self.rt.run_scalar(&art, &[wa, wb])
    }
}
