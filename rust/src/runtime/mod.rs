//! PJRT runtime: loads the AOT-compiled XLA artifacts produced by
//! `make artifacts` (L2 JAX graphs wrapping L1 Pallas kernels, lowered to HLO
//! text) and executes them from the Rust request path. Compilation happens
//! once per artifact and is cached; the hot path is execute-only.
//!
//! The executor proper wraps the `xla` crate and is gated behind the `xla`
//! cargo feature so that default builds work against an empty offline
//! registry. Without the feature a stub with the identical API is compiled
//! whose `Runtime::load` always errors; every caller (CLI `offload`,
//! `perf_hotpath`, the runtime integration tests) already treats a load
//! failure as "skip the offload path", so behavior degrades gracefully.

pub mod artifact;
pub mod densify;
#[cfg(feature = "xla")]
pub mod executor;
#[cfg(not(feature = "xla"))]
#[path = "executor_stub.rs"]
pub mod executor;
pub mod offload;

pub use artifact::{Artifact, Manifest};
pub use executor::Runtime;
pub use offload::XlaEntropy;
