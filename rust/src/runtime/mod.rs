//! PJRT runtime: loads the AOT-compiled XLA artifacts produced by
//! `make artifacts` (L2 JAX graphs wrapping L1 Pallas kernels, lowered to HLO
//! text) and executes them from the Rust request path. Compilation happens
//! once per artifact and is cached; the hot path is execute-only.

pub mod artifact;
pub mod densify;
pub mod executor;
pub mod offload;

pub use artifact::{Artifact, Manifest};
pub use executor::Runtime;
pub use offload::XlaEntropy;
