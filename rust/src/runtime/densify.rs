//! Graph → padded dense f32 weight matrix for the fixed-size XLA artifacts.
//! Zero padding appends isolated nodes, which leaves every quantity the
//! artifacts compute invariant: trace(L), Q, λ_max and the positive
//! eigenspectrum are all unchanged (padding only adds zero eigenvalues).

use crate::graph::Graph;
use anyhow::{ensure, Result};

/// Row-major n×n f32 weight matrix padded with zeros to `size`.
pub fn padded_weights_f32(g: &Graph, size: usize) -> Result<Vec<f32>> {
    let n = g.num_nodes();
    ensure!(n <= size, "graph has {n} nodes, artifact only fits {size}");
    let mut w = vec![0.0f32; size * size];
    for (i, j, wij) in g.edges() {
        w[i as usize * size + j as usize] = wij as f32;
        w[j as usize * size + i as usize] = wij as f32;
    }
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_with_zeros() {
        let g = Graph::from_edges(2, &[(0, 1, 2.0)]);
        let w = padded_weights_f32(&g, 4).unwrap();
        assert_eq!(w.len(), 16);
        // finger-lint: allow(FL003): f32 lattice of exact constants; the bit macro is f64-typed
        assert_eq!(w[0 * 4 + 1], 2.0);
        // finger-lint: allow(FL003): f32 lattice of exact constants; the bit macro is f64-typed
        assert_eq!(w[1 * 4 + 0], 2.0);
        // finger-lint: allow(FL003): exact zero sentinel over exact f32 constants
        assert_eq!(w.iter().filter(|&&x| x != 0.0).count(), 2);
    }

    #[test]
    fn rejects_too_small() {
        let g = Graph::new(5);
        assert!(padded_weights_f32(&g, 4).is_err());
    }

    #[test]
    fn padding_preserves_q() {
        // Q computed on padded graph equals Q on the original
        let mut rng = crate::util::Pcg64::new(1);
        let g = crate::generators::erdos_renyi(20, 0.2, &mut rng);
        let w = padded_weights_f32(&g, 32).unwrap();
        let mut padded = Graph::new(32);
        for i in 0..32 {
            for j in (i + 1)..32 {
                let v = w[i * 32 + j] as f64;
                if v > 0.0 {
                    padded.set_weight(i as u32, j as u32, v);
                }
            }
        }
        let q1 = crate::entropy::quadratic_q(&g);
        let q2 = crate::entropy::quadratic_q(&padded);
        assert!((q1 - q2).abs() < 1e-6, "{q1} vs {q2}");
    }
}
