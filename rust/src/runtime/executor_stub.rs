//! Stub executor compiled when the `xla` cargo feature is disabled (the
//! default — offline registries without the `xla` crate closure). Mirrors the
//! public API of `executor.rs` exactly; `Runtime::load` always fails, which
//! callers already handle as "offload unavailable, skip".

use super::artifact::{Artifact, Manifest};
use anyhow::{bail, Result};

/// Placeholder runtime: never constructible, so the remaining methods exist
/// only to satisfy the shared API surface.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Always fails: the crate was built without the `xla` feature.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let _ = dir;
        bail!("XLA offload unavailable: built without the `xla` cargo feature");
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Unreachable in practice (`load` never succeeds); kept for API parity.
    pub fn run_scalar(&self, art: &Artifact, inputs: &[Vec<f32>]) -> Result<f64> {
        let _ = (art, inputs);
        bail!("XLA offload unavailable: built without the `xla` cargo feature");
    }

    pub fn artifact(&self, name: &str, n: usize) -> Result<Artifact> {
        let _ = (name, n);
        bail!("XLA offload unavailable: built without the `xla` cargo feature");
    }

    pub fn cached_count(&self) -> usize {
        0
    }
}
