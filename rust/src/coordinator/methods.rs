//! The dissimilarity-method registry: every method the paper evaluates
//! (Tables 2/3, Fig 4) behind a single sequence-scoring interface, so the
//! experiment drivers iterate methods uniformly.
//!
//! A method consumes a `GraphSequence` and emits one score per consecutive
//! pair. Pairwise metrics adapt trivially; FINGER-JS (Incremental) threads a
//! `FingerState` through the delta stream; VNGE-NL/GL use the paper's
//! supplement-J scoring (absolute consecutive entropy difference).

use crate::distance::{self, DeltaConOpts, LambdaMatrix};
use crate::entropy::{self, FingerState};
use crate::graph::{Graph, GraphSequence};

/// Method category (used for reporting and for choosing applicable methods
/// per experiment, e.g. VEO is excluded from weighted-graph tasks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    FingerFast,
    FingerIncremental,
    Baseline,
    SupportOnly,
    DegreeDistribution,
}

/// A registered dissimilarity method.
pub struct Method {
    pub name: &'static str,
    pub kind: MethodKind,
    score: Box<dyn Fn(&GraphSequence) -> Vec<f64> + Send + Sync>,
}

impl Method {
    /// Score every consecutive pair of the sequence (length T−1).
    pub fn score_sequence(&self, seq: &GraphSequence) -> Vec<f64> {
        (self.score)(seq)
    }

    fn pairwise(
        name: &'static str,
        kind: MethodKind,
        f: impl Fn(&Graph, &Graph) -> f64 + Send + Sync + 'static,
    ) -> Self {
        Self {
            name,
            kind,
            score: Box::new(move |seq| seq.pairs().map(|(a, b)| f(a, b)).collect()),
        }
    }

    /// Per-snapshot scalar scored as |x_{t+1} − x_t| (supplement §J).
    fn snapshot_diff(
        name: &'static str,
        kind: MethodKind,
        f: impl Fn(&Graph) -> f64 + Send + Sync + 'static,
    ) -> Self {
        Self {
            name,
            kind,
            score: Box::new(move |seq| {
                let vals: Vec<f64> = seq.iter().map(&f).collect();
                vals.windows(2).map(|w| (w[1] - w[0]).abs()).collect()
            }),
        }
    }
}

/// FINGER-JS (Incremental): Algorithm 2 over the recovered delta stream.
fn finger_incremental() -> Method {
    Method {
        name: "FINGER-JS (Inc.)",
        kind: MethodKind::FingerIncremental,
        score: Box::new(|seq| {
            if seq.is_empty() {
                return Vec::new();
            }
            let mut state = FingerState::new(seq.get(0).clone());
            let mut out = Vec::with_capacity(seq.len().saturating_sub(1));
            for t in 1..seq.len() {
                let delta = crate::graph::DeltaGraph::diff(state.graph(), seq.get(t));
                out.push(distance::jsdist_incremental(&mut state, &delta));
            }
            out
        }),
    }
}

/// The full registry in the paper's Table 2/3 column order, plus the
/// supplement-S2 extras (VEO and degree-distribution distances).
pub fn all_methods() -> Vec<Method> {
    let mut v = core_methods();
    v.push(Method::pairwise("VEO", MethodKind::SupportOnly, distance::veo_score));
    v.push(Method::pairwise("Cosine dist.", MethodKind::DegreeDistribution, distance::cosine_distance));
    v.push(Method::pairwise(
        "Bhattacharyya",
        MethodKind::DegreeDistribution,
        distance::bhattacharyya_distance,
    ));
    v.push(Method::pairwise(
        "Hellinger",
        MethodKind::DegreeDistribution,
        distance::hellinger_distance,
    ));
    v
}

/// The nine methods of Table 2 / Fig 4.
pub fn core_methods() -> Vec<Method> {
    vec![
        Method::pairwise("FINGER-JS (Fast)", MethodKind::FingerFast, distance::jsdist_fast),
        finger_incremental(),
        Method::pairwise("DeltaCon", MethodKind::Baseline, |a, b| {
            1.0 - distance::deltacon_similarity(a, b, &DeltaConOpts::default())
        }),
        Method::pairwise("RMD", MethodKind::Baseline, |a, b| {
            distance::rmd_distance(a, b, &DeltaConOpts::default())
        }),
        Method::pairwise("λ dist. (Adj.)", MethodKind::Baseline, |a, b| {
            distance::lambda_distance(a, b, 6, LambdaMatrix::Adjacency)
        }),
        Method::pairwise("λ dist. (Lap.)", MethodKind::Baseline, |a, b| {
            distance::lambda_distance(a, b, 6, LambdaMatrix::Laplacian)
        }),
        Method::pairwise("GED", MethodKind::SupportOnly, distance::graph_edit_distance),
        Method::snapshot_diff("VNGE-NL", MethodKind::Baseline, entropy::baselines::vnge_nl),
        Method::snapshot_diff("VNGE-GL", MethodKind::Baseline, entropy::baselines::vnge_gl),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::util::Pcg64;

    fn small_seq() -> GraphSequence {
        let mut rng = Pcg64::new(1);
        let g0 = generators::erdos_renyi(40, 0.1, &mut rng);
        let g1 = generators::erdos_renyi(40, 0.12, &mut rng);
        let g2 = generators::erdos_renyi(40, 0.14, &mut rng);
        GraphSequence::from_snapshots(vec![g0, g1, g2])
    }

    #[test]
    fn registry_sizes() {
        assert_eq!(core_methods().len(), 9);
        assert_eq!(all_methods().len(), 13); // + VEO + 3 degree distances
    }

    #[test]
    fn every_method_scores_every_pair() {
        let seq = small_seq();
        for m in all_methods() {
            let s = m.score_sequence(&seq);
            assert_eq!(s.len(), 2, "{} returned {} scores", m.name, s.len());
            assert!(s.iter().all(|v| v.is_finite()), "{} non-finite", m.name);
            assert!(s.iter().all(|&v| v >= 0.0), "{} negative score", m.name);
        }
    }

    #[test]
    fn identical_sequence_scores_zero() {
        let mut rng = Pcg64::new(2);
        let g = generators::erdos_renyi(30, 0.15, &mut rng);
        let seq = GraphSequence::from_snapshots(vec![g.clone(), g.clone(), g]);
        for m in all_methods() {
            let s = m.score_sequence(&seq);
            for v in s {
                assert!(v.abs() < 1e-6, "{} scored {v} on identical graphs", m.name);
            }
        }
    }

    #[test]
    fn incremental_close_to_batch_htilde() {
        let seq = small_seq();
        let inc = finger_incremental().score_sequence(&seq);
        let batch: Vec<f64> = seq
            .pairs()
            .map(|(a, b)| distance::jsdist_with(a, b, entropy::finger_htilde))
            .collect();
        for (x, y) in inc.iter().zip(&batch) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn names_unique() {
        let names: Vec<_> = all_methods().iter().map(|m| m.name).collect();
        let set: std::collections::HashSet<_> = names.iter().collect();
        assert_eq!(set.len(), names.len());
    }
}
