//! Render experiment results as paper-style text tables (used by benches,
//! examples and the CLI).

use crate::coordinator::experiments::{ApproxRow, Fig4Row, Table3Row, WikiRun};
use crate::util::fmt::{pct, secs, Table};

/// Fig 1 / Fig 2 style table of entropy approximations.
pub fn approx_table(rows: &[ApproxRow], sweep_label: &str) -> String {
    let mut t = Table::new(&[
        sweep_label, "n", "H", "Ĥ", "H̃", "AE(Ĥ)", "AE(H̃)", "SAE(Ĥ)", "CTRR(Ĥ)", "CTRR(H̃)",
        "t(H)", "t(Ĥ)",
    ]);
    for r in rows {
        let param = if sweep_label.contains("p_ws") {
            format!("{:.3}", r.p_ws)
        } else if sweep_label == "n" {
            format!("{}", r.n)
        } else {
            format!("{:.1}", r.avg_degree)
        };
        t.row(vec![
            param,
            r.n.to_string(),
            format!("{:.4}", r.h),
            format!("{:.4}", r.hhat),
            format!("{:.4}", r.htilde),
            format!("{:.4}", r.ae_hat),
            format!("{:.4}", r.ae_tilde),
            format!("{:.5}", r.sae_hat),
            pct(r.ctrr_hat),
            pct(r.ctrr_tilde),
            secs(r.time_h),
            secs(r.time_hat),
        ]);
    }
    t.render()
}

/// Table 2 / S1 per-dataset block.
pub fn wiki_table(run: &WikiRun) -> String {
    let mut out = format!(
        "dataset={} | graphs={} | max nodes={} | max edges={}\n",
        run.dataset, run.num_graphs, run.max_nodes, run.max_edges
    );
    let mut t = Table::new(&["method", "PCC", "SRCC", "time"]);
    for r in &run.rows {
        t.row(vec![
            r.method.clone(),
            format!("{:+.4}", r.pcc),
            format!("{:+.4}", r.srcc),
            secs(r.seconds),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Fig 3-style series dump (proxy + each method, one line per pair).
pub fn series_dump(run: &WikiRun) -> String {
    let mut out = String::from("pair proxy");
    for r in &run.rows {
        out.push(' ');
        out.push_str(&r.method.replace(' ', "_"));
    }
    out.push('\n');
    for t in 0..run.proxy.len() {
        out.push_str(&format!("{t} {:.5}", run.proxy[t]));
        for r in &run.rows {
            out.push_str(&format!(" {:.5}", r.series[t]));
        }
        out.push('\n');
    }
    out
}

/// Fig 4 block: TDS curves + detections.
pub fn bifurcation_table(rows: &[Fig4Row], ground_truth: usize) -> String {
    let mut t = Table::new(&["method", "detected (1-based)", "correct", "TDS"]);
    for r in rows {
        let tds: Vec<String> = r.tds.iter().map(|v| format!("{v:.3}")).collect();
        t.row(vec![
            r.method.clone(),
            format!("{:?}", r.detected),
            if r.correct { "YES".into() } else { "no".into() },
            tds.join(","),
        ]);
    }
    format!("ground-truth bifurcation at measurement {ground_truth}\n{}", t.render())
}

/// Table 3 / S2 block.
pub fn dos_table(rows: &[Table3Row], xs: &[f64]) -> String {
    let mut header: Vec<String> = vec!["method".into()];
    header.extend(xs.iter().map(|x| format!("X={:.0}%", x * 100.0)));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for r in rows {
        let mut cells = vec![r.method.clone()];
        cells.extend(r.rates.iter().map(|v| pct(*v)));
        t.row(cells);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dos_table_renders() {
        let rows = vec![Table3Row { method: "m".into(), rates: vec![0.5, 1.0] }];
        let s = dos_table(&rows, &[0.01, 0.1]);
        assert!(s.contains("X=1%"));
        assert!(s.contains("50.0%"));
        assert!(s.contains("100.0%"));
    }

    #[test]
    fn bifurcation_table_renders() {
        let rows = vec![Fig4Row {
            method: "m".into(),
            tds: vec![1.0, 0.5, 1.0],
            detected: vec![2],
            correct: true,
        }];
        let s = bifurcation_table(&rows, 2);
        assert!(s.contains("YES"));
        assert!(s.contains("ground-truth bifurcation at measurement 2"));
    }
}
