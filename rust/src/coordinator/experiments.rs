//! Experiment drivers regenerating the paper's tables and figures. Each
//! driver returns structured rows; the benches and examples render them via
//! `coordinator::report`. Scales default to laptop size; every driver takes
//! explicit parameters so `--full` runs can approach paper scale.

use crate::anomaly;
use crate::coordinator::methods::{all_methods, core_methods, Method};
use crate::datasets::{dos_inject, hic_sequence, oregon_snapshots, wiki_stream};
use crate::datasets::{HicConfig, OregonConfig, WikiConfig};
use crate::distance::veo_score;
use crate::entropy::{exact_vnge, finger_hhat, finger_htilde};
use crate::graph::{Graph, GraphSequence};
use crate::util::stats::{mean, pearson, spearman};
use crate::util::timer::{ctrr, time_it};
use crate::util::Pcg64;

/// Random-graph families used by Figures 1–2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphModel {
    Er,
    Ba,
    Ws,
}

impl GraphModel {
    pub fn name(&self) -> &'static str {
        match self {
            GraphModel::Er => "ER",
            GraphModel::Ba => "BA",
            GraphModel::Ws => "WS",
        }
    }

    /// Sample a graph with target average degree d̄ (and WS rewiring p_ws).
    pub fn sample(&self, n: usize, avg_degree: f64, p_ws: f64, rng: &mut Pcg64) -> Graph {
        match self {
            GraphModel::Er => crate::generators::erdos_renyi_avg_degree(n, avg_degree, rng),
            GraphModel::Ba => {
                let m = ((avg_degree / 2.0).round() as usize).max(1);
                crate::generators::barabasi_albert(n, m, rng)
            }
            GraphModel::Ws => {
                let k = ((avg_degree / 2.0).round() as usize).max(1) * 2;
                crate::generators::watts_strogatz(n, k.min(n - 1 - (n % 2)), p_ws, rng)
            }
        }
    }
}

/// One row of the Fig 1 / Fig 2 style entropy-approximation comparison,
/// averaged over trials.
#[derive(Debug, Clone)]
pub struct ApproxRow {
    pub model: &'static str,
    pub n: usize,
    pub avg_degree: f64,
    pub p_ws: f64,
    pub h: f64,
    pub hhat: f64,
    pub htilde: f64,
    /// approximation errors H − Ĥ, H − H̃
    pub ae_hat: f64,
    pub ae_tilde: f64,
    /// scaled approximation errors AE/ln n
    pub sae_hat: f64,
    pub sae_tilde: f64,
    /// computation-time reduction ratios vs exact H
    pub ctrr_hat: f64,
    pub ctrr_tilde: f64,
    pub time_h: f64,
    pub time_hat: f64,
    pub time_tilde: f64,
}

/// Measure H, Ĥ, H̃ (values + times) on graphs drawn from `model`,
/// averaged over `trials`.
pub fn approx_comparison(
    model: GraphModel,
    n: usize,
    avg_degree: f64,
    p_ws: f64,
    trials: usize,
    seed: u64,
) -> ApproxRow {
    let mut acc = ApproxRow {
        model: model.name(),
        n,
        avg_degree,
        p_ws,
        h: 0.0,
        hhat: 0.0,
        htilde: 0.0,
        ae_hat: 0.0,
        ae_tilde: 0.0,
        sae_hat: 0.0,
        sae_tilde: 0.0,
        ctrr_hat: 0.0,
        ctrr_tilde: 0.0,
        time_h: 0.0,
        time_hat: 0.0,
        time_tilde: 0.0,
    };
    for t in 0..trials {
        let mut rng = Pcg64::new(seed.wrapping_add(t as u64));
        let g = model.sample(n, avg_degree, p_ws, &mut rng);
        let (h, th) = time_it(|| exact_vnge(&g));
        let (hh, tha) = time_it(|| finger_hhat(&g));
        let (ht, tti) = time_it(|| finger_htilde(&g));
        acc.h += h;
        acc.hhat += hh;
        acc.htilde += ht;
        acc.time_h += th;
        acc.time_hat += tha;
        acc.time_tilde += tti;
    }
    let k = trials.max(1) as f64;
    acc.h /= k;
    acc.hhat /= k;
    acc.htilde /= k;
    acc.time_h /= k;
    acc.time_hat /= k;
    acc.time_tilde /= k;
    acc.ae_hat = acc.h - acc.hhat;
    acc.ae_tilde = acc.h - acc.htilde;
    let ln_n = (n as f64).ln();
    acc.sae_hat = acc.ae_hat / ln_n;
    acc.sae_tilde = acc.ae_tilde / ln_n;
    acc.ctrr_hat = ctrr(acc.time_h, acc.time_hat);
    acc.ctrr_tilde = ctrr(acc.time_h, acc.time_tilde);
    acc
}

/// Fig 1(a,b): sweep average degree for ER/BA at fixed n.
pub fn fig1_degree_sweep(
    model: GraphModel,
    n: usize,
    degrees: &[f64],
    trials: usize,
    seed: u64,
) -> Vec<ApproxRow> {
    degrees
        .iter()
        .map(|&d| approx_comparison(model, n, d, 0.0, trials, seed ^ (d as u64)))
        .collect()
}

/// Fig 1(c)/S1: sweep WS rewiring probability at fixed n and degree.
pub fn fig1_ws_sweep(
    n: usize,
    avg_degree: f64,
    p_list: &[f64],
    trials: usize,
    seed: u64,
) -> Vec<ApproxRow> {
    p_list
        .iter()
        .map(|&p| {
            approx_comparison(GraphModel::Ws, n, avg_degree, p, trials, seed ^ ((p * 1e4) as u64))
        })
        .collect()
}

/// Fig 2/S2/S3: sweep graph size n.
pub fn fig2_size_sweep(
    model: GraphModel,
    ns: &[usize],
    avg_degree: f64,
    p_ws: f64,
    trials: usize,
    seed: u64,
) -> Vec<ApproxRow> {
    ns.iter()
        .map(|&n| approx_comparison(model, n, avg_degree, p_ws, trials, seed ^ n as u64))
        .collect()
}

/// One Table 2 row: a method's correlation with the VEO anomaly proxy plus
/// its total scoring time over the sequence.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub method: String,
    pub pcc: f64,
    pub srcc: f64,
    pub seconds: f64,
    pub series: Vec<f64>,
}

/// Summary of one wiki dataset run (Table 1 stats + Table 2/S1 rows + the
/// proxy series for Fig 3).
#[derive(Debug)]
pub struct WikiRun {
    pub dataset: String,
    pub max_nodes: usize,
    pub max_edges: usize,
    pub num_graphs: usize,
    pub proxy: Vec<f64>,
    pub rows: Vec<Table2Row>,
}

/// Table 2 / Table S1 / Fig 3 driver on one synthetic wiki stream.
pub fn run_wiki(dataset: &str, cfg: &WikiConfig) -> WikiRun {
    let stream = wiki_stream(cfg);
    let seq = GraphSequence::from_deltas(stream.initial.clone(), &stream.deltas);
    let proxy: Vec<f64> = seq.pairs().map(|(a, b)| veo_score(a, b)).collect();
    let max_nodes = seq.iter().map(|g| g.num_nodes()).max().unwrap_or(0);
    let max_edges = seq.iter().map(|g| g.num_edges()).max().unwrap_or(0);
    let mut rows = Vec::new();
    for m in core_methods() {
        let (series, secs) = time_it(|| m.score_sequence(&seq));
        rows.push(Table2Row {
            method: m.name.to_string(),
            pcc: pearson(&series, &proxy),
            srcc: spearman(&series, &proxy),
            seconds: secs,
            series,
        });
    }
    WikiRun {
        dataset: dataset.to_string(),
        max_nodes,
        max_edges,
        num_graphs: seq.len(),
        proxy,
        rows,
    }
}

/// One Fig 4 row: a method's TDS curve and detected bifurcation instants
/// (1-based measurement indices).
#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub method: String,
    pub tds: Vec<f64>,
    pub detected: Vec<usize>,
    pub correct: bool,
}

/// Fig 4 driver: bifurcation detection on the Hi-C-like sequence.
pub fn run_bifurcation(cfg: &HicConfig) -> Vec<Fig4Row> {
    let seq = hic_sequence(cfg);
    let mut rows = Vec::new();
    for m in core_methods() {
        let theta = m.score_sequence(&seq);
        let tds = anomaly::temporal_difference_score(&theta);
        let detected: Vec<usize> =
            anomaly::detect_bifurcations(&tds).iter().map(|&i| i + 1).collect(); // 1-based
        let correct = detected.contains(&cfg.bifurcation) && detected.len() == 1;
        rows.push(Fig4Row { method: m.name.to_string(), tds, detected, correct });
    }
    rows
}

/// One Table 3 row: detection rates per DoS fraction for one method.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub method: String,
    /// detection rate per X value, aligned with the input `xs`.
    pub rates: Vec<f64>,
}

/// Table 3 / S2 driver: synthesized DoS detection rates.
/// `xs` are attack fractions (e.g. [0.01, 0.03, 0.05, 0.10]);
/// `extended` includes the supplement's VEO/degree-distribution columns.
pub fn run_dos(
    cfg: &OregonConfig,
    xs: &[f64],
    trials: usize,
    extended: bool,
    seed: u64,
) -> Vec<Table3Row> {
    let base = oregon_snapshots(cfg);
    let methods: Vec<Method> = if extended { all_methods() } else { core_methods() };
    let mut rows: Vec<Table3Row> =
        methods.iter().map(|m| Table3Row { method: m.name.to_string(), rates: Vec::new() }).collect();
    for &x in xs {
        let mut hits = vec![0usize; methods.len()];
        for trial in 0..trials {
            let mut rng = Pcg64::new(seed ^ ((x * 1e4) as u64) ^ ((trial as u64) << 20));
            let event = dos_inject(&base, x, &mut rng);
            for (mi, m) in methods.iter().enumerate() {
                let scores = m.score_sequence(&event.seq);
                let top2 = crate::util::stats::top_k_indices(&scores, 2);
                if event.affected_pairs.iter().any(|p| top2.contains(p)) {
                    hits[mi] += 1;
                }
            }
        }
        for (mi, h) in hits.iter().enumerate() {
            rows[mi].rates.push(*h as f64 / trials.max(1) as f64);
        }
    }
    rows
}

/// Mean scaled approximation error over a size sweep — convergence summary
/// used in tests and EXPERIMENTS.md.
pub fn sae_trend(rows: &[ApproxRow]) -> (f64, f64) {
    let first = rows.first().map(|r| r.sae_hat).unwrap_or(0.0);
    let last = rows.last().map(|r| r.sae_hat).unwrap_or(0.0);
    (first, last)
}

/// Average CTRR across rows.
pub fn mean_ctrr(rows: &[ApproxRow]) -> (f64, f64) {
    (
        mean(&rows.iter().map(|r| r.ctrr_hat).collect::<Vec<_>>()),
        mean(&rows.iter().map(|r| r.ctrr_tilde).collect::<Vec<_>>()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_comparison_orders_entropies() {
        let row = approx_comparison(GraphModel::Er, 150, 12.0, 0.0, 2, 42);
        assert!(row.htilde <= row.hhat + 1e-9);
        assert!(row.hhat <= row.h + 1e-6);
        assert!(row.ae_hat >= -1e-9 && row.ae_tilde >= row.ae_hat - 1e-9);
    }

    #[test]
    fn fig1_ae_decays_with_degree() {
        let rows = fig1_degree_sweep(GraphModel::Er, 200, &[6.0, 40.0], 2, 7);
        assert!(rows[1].ae_hat < rows[0].ae_hat);
    }

    #[test]
    fn ws_more_regular_less_error() {
        let rows = fig1_ws_sweep(200, 10.0, &[0.01, 0.9], 2, 9);
        assert!(rows[0].ae_hat <= rows[1].ae_hat + 1e-9, "{rows:?}");
    }

    #[test]
    fn wiki_run_produces_all_rows() {
        let cfg = WikiConfig {
            months: 8,
            initial_nodes: 60,
            growth_per_month: 15,
            ..Default::default()
        };
        let run = run_wiki("test", &cfg);
        assert_eq!(run.rows.len(), 9);
        assert_eq!(run.proxy.len(), 7);
        for r in &run.rows {
            assert_eq!(r.series.len(), 7);
            assert!(r.pcc.abs() <= 1.0 + 1e-9);
            assert!(r.srcc.abs() <= 1.0 + 1e-9);
        }
        assert!(run.max_nodes >= 60 + 7 * 15);
    }

    #[test]
    fn bifurcation_finger_correct() {
        let cfg = HicConfig { dim: 100, band: 12, ..Default::default() };
        let rows = run_bifurcation(&cfg);
        let finger = rows.iter().find(|r| r.method.contains("Fast")).unwrap();
        assert!(finger.detected.contains(&6), "detected {:?}", finger.detected);
    }

    #[test]
    fn dos_rates_increase_with_x() {
        let cfg = OregonConfig { nodes: 250, ..Default::default() };
        let rows = run_dos(&cfg, &[0.01, 0.10], 6, false, 3);
        let finger = &rows[0];
        assert_eq!(finger.method, "FINGER-JS (Fast)");
        assert!(finger.rates[1] >= finger.rates[0], "{:?}", finger.rates);
    }
}
