//! L3 coordination: the unified method registry (FINGER + all baselines
//! behind one trait) and the experiment drivers that regenerate every table
//! and figure of the paper (shared by `rust/benches/*` and `examples/*`).

pub mod experiments;
pub mod methods;
pub mod report;

pub use methods::{all_methods, core_methods, Method, MethodKind};
