//! Tiny flag parser: `prog subcommand --key value --flag positional`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = program name skipped
    /// by `from_env`). Tokens starting with `--` become options when followed
    /// by a non-`--` token, otherwise boolean flags.
    pub fn parse(tokens: &[String]) -> Self {
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&tokens)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Parse a comma-separated option (`--shards 1,2,4`) into a list,
    /// falling back to `default` when the option is missing or any element
    /// fails to parse (consistent with `get_parsed`'s forgiving contract).
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            Some(raw) => {
                let parsed: Option<Vec<T>> =
                    raw.split(',').map(|t| t.trim().parse().ok()).collect();
                match parsed {
                    Some(v) if !v.is_empty() => v,
                    _ => default.to_vec(),
                }
            }
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        // `--key value` binds greedily, so boolean flags go last (or use
        // --flag=true); this matches how the CLI documents itself.
        let a = Args::parse(&toks("entropy --n 500 --model er input.edges --quick"));
        assert_eq!(a.subcommand.as_deref(), Some("entropy"));
        assert_eq!(a.get("n"), Some("500"));
        assert_eq!(a.get("model"), Some("er"));
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["input.edges"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&toks("run --seed=42"));
        assert_eq!(a.get_parsed("seed", 0u64), 42);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&toks("x --verbose"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn default_on_missing_or_bad() {
        let a = Args::parse(&toks("x --n abc"));
        assert_eq!(a.get_parsed("n", 7usize), 7);
        assert_bits_eq!(a.get_parsed("missing", 3.5f64), 3.5);
    }

    #[test]
    fn empty() {
        let a = Args::parse(&[]);
        assert!(a.subcommand.is_none());
    }

    #[test]
    fn comma_separated_lists() {
        let a = Args::parse(&toks("serve-bench --shards 1,2,4"));
        assert_eq!(a.get_list("shards", &[8usize]), vec![1, 2, 4]);
        assert_eq!(a.get_list("missing", &[8usize]), vec![8]);
        let b = Args::parse(&toks("serve-bench --shards 1,x"));
        assert_eq!(b.get_list("shards", &[8usize]), vec![8], "bad element falls back whole");
    }
}
