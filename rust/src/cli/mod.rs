//! Command-line and configuration substrate (clap/serde are unavailable
//! offline): a small flag parser and a typed TOML-subset config loader.

pub mod args;
pub mod config;

pub use args::Args;
pub use config::Config;
