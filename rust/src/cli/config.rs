//! TOML-subset config parser: `[section]` headers, `key = value` pairs with
//! string/number/bool values, `#` comments. Enough to express every knob the
//! coordinator exposes without a serde dependency.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Parsed configuration: `section.key -> raw string value`.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim();
            let v = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')).unwrap_or(v);
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            values.insert(key, v.to_string());
        }
        Ok(Self { values })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# pipeline settings
[stream]
channel_capacity = 128
anomaly_sigma = 2.5
enabled = true
name = "wiki run"

[wiki]
months = 48
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_or("stream.channel_capacity", 0usize), 128);
        assert!((c.get_or("stream.anomaly_sigma", 0.0f64) - 2.5).abs() < 1e-12);
        assert!(c.get_bool("stream.enabled", false));
        assert_eq!(c.get("stream.name"), Some("wiki run"));
        assert_eq!(c.get_or("wiki.months", 0usize), 48);
    }

    #[test]
    fn missing_keys_fall_back() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_or("x.y", 9usize), 9);
        assert!(!c.get_bool("x.z", false));
        assert!(c.is_empty());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("[s]\njust a line\n").is_err());
    }

    #[test]
    fn comments_stripped() {
        let c = Config::parse("a = 1 # trailing\n").unwrap();
        assert_eq!(c.get_or("a", 0u32), 1);
    }

    #[test]
    fn sectionless_keys() {
        let c = Config::parse("top = 5\n").unwrap();
        assert_eq!(c.get_or("top", 0u32), 5);
    }
}
