//! End-to-end TCP traffic demo for the event-driven front end: boot the
//! server on an ephemeral loopback port, then sweep connection tiers
//! (10 / 100 / 1000 by default) against it — each tier replayed twice over
//! the same mixed dataset-preset workload (wiki + DoS + Hi-C + synthetic
//! tenants), once on the text wire and once on the binary wire (the server
//! negotiates the codec per connection on its first byte). Every tier
//! prints end-to-end events/s and p99 request latency per wire, asserts
//! the two wires scored bit-for-bit identically, and the demo finishes
//! with a live stats probe, a `CLOSE`, and a graceful shutdown.
//!
//! The 1000-connection tier holds ~2000 sockets in this one process
//! (client and server ends both live here) — raise the fd ceiling first
//! (`ulimit -n 4096`) or pass a smaller sweep.
//!
//! ```bash
//! cargo run --release --offline --example tcp_traffic \
//!     [-- --connections 10,100,1000 --windows 3 --events 12 --shards 4 --threads 2]
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::cli::Args;
use finger::net::{NetClient, NetConfig, NetServer, TrafficConfig, TrafficReport, Wire};
use finger::service::{ServiceConfig, TenantPreset, TenantWorkloadConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let service_cfg = ServiceConfig {
        shards: args.get_parsed("shards", 4usize).max(1),
        ..Default::default()
    };
    let mut net_cfg = NetConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() };
    net_cfg.event_threads = args.get_parsed("threads", net_cfg.event_threads).max(1);
    let client_timeout = net_cfg.client_timeout();
    let threads = net_cfg.event_threads;
    let server = NetServer::bind(service_cfg, net_cfg)?;
    let addr = server.local_addr().to_string();
    println!(
        "server listening on {addr} ({threads} event-loop threads, \
         wire negotiated per connection)"
    );
    let server_thread = std::thread::spawn(move || server.run());

    let tiers = args.get_list("connections", &[10usize, 100, 1000]);
    let windows = args.get_parsed("windows", 3usize).max(2);
    let events = args.get_parsed("events", 12usize).max(1);
    let nodes = args.get_parsed("nodes", 32usize).max(24);

    println!(
        "{:<8} {:<12} {:>10} {:>14} {:>10}",
        "wire", "connections", "sessions", "events/s", "p99(us)"
    );
    let mut last_pair: Option<(TrafficReport, TrafficReport)> = None;
    for &tier in &tiers {
        let workload = TenantWorkloadConfig {
            // one tenant per connection: replay() clamps the connection
            // count to the session count, so sessions track the tier
            sessions: tier.max(1),
            windows,
            events_per_window: events,
            nodes_per_session: nodes,
            presets: vec![
                TenantPreset::Wiki,
                TenantPreset::Dos,
                TenantPreset::HiC,
                TenantPreset::Synthetic,
            ],
            seed: args.get_parsed("seed", 0x7C9u64),
        };
        // same workload, same server, both wires — OPEN resets each
        // session, so the second replay starts from scratch and the two
        // runs are comparable
        let mut pair: Vec<TrafficReport> = Vec::new();
        for wire in [Wire::Text, Wire::Binary] {
            let report = finger::net::run_load(&TrafficConfig {
                addr: addr.clone(),
                wire,
                client_timeout,
                connections: tier.max(1),
                workload: workload.clone(),
                query_sessions: true,
                shutdown_after: false,
                live_stats: false,
                check_metrics: false,
            })?;
            println!(
                "{:<8} {:<12} {:>10} {:>14.0} {:>10}",
                wire.name(),
                report.connections,
                report.sessions,
                report.events_per_sec,
                report.p99_us,
            );
            pair.push(report);
        }
        let binary = pair.pop().expect("binary report");
        let text = pair.pop().expect("text report");
        // both wires replayed identical streams → identical scores, bit
        // for bit, at every connection count
        for (t, b) in text.snapshots.iter().zip(&binary.snapshots) {
            assert_eq!(t.htilde.to_bits(), b.htilde.to_bits(), "{}: wires disagree", t.id);
        }
        println!(
            "  tier {tier}: binary/text throughput {:.2}x — p50 text {}us / binary {}us",
            binary.events_per_sec / text.events_per_sec.max(1e-12),
            text.p50_us,
            binary.p50_us,
        );
        last_pair = Some((text, binary));
    }

    // live operator view, then retire one session with CLOSE
    let mut probe = NetClient::connect_with(addr.as_str(), Wire::Binary, client_timeout)?;
    let stats = probe.stats()?;
    println!("queue depths at idle: {:?} ({} events accepted)", stats.depths, stats.submitted);
    if let Some((_, binary)) = &last_pair {
        if let Some(first) = binary.snapshots.first() {
            let closed = probe.close(&first.id)?.expect("session is live");
            println!(
                "closed {:<16} final: windows={} events={} H̃={:.4}",
                closed.id, closed.windows, closed.events, closed.htilde
            );
            assert!(probe.query(&first.id)?.is_none(), "closed session must be gone");
        }
    }
    probe.quit()?;

    NetClient::connect(addr.as_str())?.shutdown_server()?;
    let svc_report = server_thread.join().expect("server thread")?;
    println!(
        "graceful shutdown: service drained {} events across {} sessions",
        svc_report.total_events,
        svc_report.sessions.len()
    );
    Ok(())
}
