//! End-to-end TCP traffic demo: boot the network front end on an ephemeral
//! loopback port, then replay the same mixed dataset-preset workload (wiki
//! + DoS + Hi-C + synthetic tenants) twice against that one server — once
//! on the text wire, once on the binary wire (the server negotiates the
//! codec per connection on its first byte) — print the throughput ratio,
//! query live stats, retire one session with `CLOSE`, and shut the server
//! down gracefully.
//!
//! ```bash
//! cargo run --release --offline --example tcp_traffic \
//!     [-- --sessions 16 --connections 4 --windows 6 --shards 4]
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::cli::Args;
use finger::net::{NetClient, NetConfig, NetServer, TrafficConfig, TrafficReport, Wire};
use finger::service::{ServiceConfig, TenantPreset, TenantWorkloadConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let service_cfg = ServiceConfig {
        shards: args.get_parsed("shards", 4usize).max(1),
        ..Default::default()
    };
    let net_cfg = NetConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() };
    let client_timeout = net_cfg.client_timeout();
    let server = NetServer::bind(service_cfg, net_cfg)?;
    let addr = server.local_addr().to_string();
    println!("server listening on {addr} (wire negotiated per connection)");
    let server_thread = std::thread::spawn(move || server.run());

    let workload = TenantWorkloadConfig {
        sessions: args.get_parsed("sessions", 16usize).max(1),
        windows: args.get_parsed("windows", 6usize).max(2),
        events_per_window: args.get_parsed("events", 30usize).max(1),
        nodes_per_session: args.get_parsed("nodes", 48usize).max(24),
        presets: vec![
            TenantPreset::Wiki,
            TenantPreset::Dos,
            TenantPreset::HiC,
            TenantPreset::Synthetic,
        ],
        seed: args.get_parsed("seed", 0x7C9u64),
    };
    let connections = args.get_parsed("connections", 4usize).max(1);

    // same workload, same server, both wires — OPEN resets each session, so
    // the second replay starts from scratch and the runs are comparable
    let mut reports: Vec<TrafficReport> = Vec::new();
    for wire in [Wire::Text, Wire::Binary] {
        let report = finger::net::run_load(&TrafficConfig {
            addr: addr.clone(),
            wire,
            client_timeout,
            connections,
            workload: workload.clone(),
            query_sessions: true,
            shutdown_after: false,
        })?;
        println!(
            "{:>6} wire: {} events for {} sessions over {} connections in {:.3}s \
             → {:.0} events/s end-to-end ({} windows, {} anomalous)",
            wire.name(),
            report.events_sent,
            report.sessions,
            report.connections,
            report.wall_secs,
            report.events_per_sec,
            report.windows,
            report.anomalies,
        );
        reports.push(report);
    }
    let (text, binary) = (&reports[0], &reports[1]);
    println!(
        "binary/text throughput ratio: {:.2}x",
        binary.events_per_sec / text.events_per_sec.max(1e-12)
    );
    // both wires replayed identical streams → identical scores, bit for bit
    for (t, b) in text.snapshots.iter().zip(&binary.snapshots) {
        assert_eq!(t.htilde.to_bits(), b.htilde.to_bits(), "{}: wires disagree", t.id);
    }
    for snap in binary.snapshots.iter().take(4) {
        println!(
            "  {:<16} windows={:<3} H̃={:.4} n={} m={} anomalies={}",
            snap.id, snap.windows, snap.htilde, snap.nodes, snap.edges, snap.anomalies
        );
    }

    // live operator view, then retire one session with CLOSE
    let mut probe = NetClient::connect_with(addr.as_str(), Wire::Binary, client_timeout)?;
    let stats = probe.stats()?;
    println!("queue depths at idle: {:?} ({} events accepted)", stats.depths, stats.submitted);
    if let Some(first) = binary.snapshots.first() {
        let closed = probe.close(&first.id)?.expect("session is live");
        println!(
            "closed {:<16} final: windows={} events={} H̃={:.4}",
            closed.id, closed.windows, closed.events, closed.htilde
        );
        assert!(probe.query(&first.id)?.is_none(), "closed session must be gone");
    }
    probe.quit()?;

    NetClient::connect(addr.as_str())?.shutdown_server()?;
    let svc_report = server_thread.join().expect("server thread")?;
    println!(
        "graceful shutdown: service drained {} events across {} sessions",
        svc_report.total_events,
        svc_report.sessions.len()
    );
    Ok(())
}
