//! End-to-end TCP traffic demo: boot the network front end on an ephemeral
//! loopback port, replay a mixed dataset-preset workload (wiki + DoS + Hi-C
//! + synthetic tenants) over concurrent connections, query live stats, then
//! shut the server down gracefully and print its final report.
//!
//! ```bash
//! cargo run --release --offline --example tcp_traffic \
//!     [-- --sessions 16 --connections 4 --windows 6 --shards 4]
//! ```

use finger::cli::Args;
use finger::net::{NetClient, NetConfig, NetServer, TrafficConfig};
use finger::service::{ServiceConfig, TenantPreset, TenantWorkloadConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let service_cfg = ServiceConfig {
        shards: args.get_parsed("shards", 4usize).max(1),
        ..Default::default()
    };
    let net_cfg = NetConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() };
    let server = NetServer::bind(service_cfg, net_cfg)?;
    let addr = server.local_addr().to_string();
    println!("server listening on {addr}");
    let server_thread = std::thread::spawn(move || server.run());

    let workload = TenantWorkloadConfig {
        sessions: args.get_parsed("sessions", 16usize).max(1),
        windows: args.get_parsed("windows", 6usize).max(2),
        events_per_window: args.get_parsed("events", 30usize).max(1),
        nodes_per_session: args.get_parsed("nodes", 48usize).max(24),
        presets: vec![
            TenantPreset::Wiki,
            TenantPreset::Dos,
            TenantPreset::HiC,
            TenantPreset::Synthetic,
        ],
        seed: args.get_parsed("seed", 0x7C9u64),
    };
    let report = finger::net::run_load(&TrafficConfig {
        addr: addr.clone(),
        connections: args.get_parsed("connections", 4usize).max(1),
        workload,
        query_sessions: true,
        shutdown_after: false,
    })?;
    println!(
        "replayed {} events for {} sessions over {} connections in {:.3}s \
         → {:.0} events/s end-to-end",
        report.events_sent,
        report.sessions,
        report.connections,
        report.wall_secs,
        report.events_per_sec,
    );
    println!("server-side: {} windows scored, {} anomalous", report.windows, report.anomalies);
    for snap in report.snapshots.iter().take(4) {
        println!(
            "  {:<16} windows={:<3} H̃={:.4} n={} m={} anomalies={}",
            snap.id, snap.windows, snap.htilde, snap.nodes, snap.edges, snap.anomalies
        );
    }

    // live operator view before shutdown
    let mut probe = NetClient::connect(addr.as_str())?;
    let stats = probe.stats()?;
    println!("queue depths at idle: {:?} ({} events accepted)", stats.depths, stats.submitted);
    probe.quit()?;

    NetClient::connect(addr.as_str())?.shutdown_server()?;
    let svc_report = server_thread.join().expect("server thread")?;
    println!(
        "graceful shutdown: service drained {} events across {} sessions",
        svc_report.total_events,
        svc_report.sessions.len()
    );
    Ok(())
}
