//! Synthesized DoS-attack detection on AS router graphs (Table 3 / S2).
//!
//! ```bash
//! cargo run --release --offline --example dos_detection [-- --nodes 2000 --trials 50 --extended]
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::cli::Args;
use finger::coordinator::{experiments, report};
use finger::datasets::OregonConfig;

fn main() {
    let args = Args::from_env();
    let cfg = OregonConfig { nodes: args.get_parsed("nodes", 2000usize), ..Default::default() };
    let trials = args.get_parsed("trials", 25usize);
    let extended = args.flag("extended");
    let xs = [0.01, 0.03, 0.05, 0.10];
    println!(
        "Oregon-like snapshots: n={} snapshots={} | {} trials per X | top-2 ranking\n",
        cfg.nodes, cfg.snapshots, trials
    );
    let rows = experiments::run_dos(&cfg, &xs, trials, extended, 7);
    println!("{}", report::dos_table(&rows, &xs));
}
