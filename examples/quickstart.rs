//! Quickstart: exact vs FINGER entropies and JS distances on small graphs.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::distance::{jsdist_exact, jsdist_fast, jsdist_incremental};
use finger::entropy::{exact_vnge, finger_hhat, finger_htilde, FingerState};
use finger::graph::DeltaGraph;
use finger::util::{fmt, timer::time_it, Pcg64};

fn main() {
    let mut rng = Pcg64::new(7);
    let n = 1000;
    let g = finger::generators::erdos_renyi_avg_degree(n, 20.0, &mut rng);
    println!("ER graph: n={} m={}", g.num_nodes(), g.num_edges());

    let (h, t_h) = time_it(|| exact_vnge(&g));
    let (hhat, t_hat) = time_it(|| finger_hhat(&g));
    let (htil, t_til) = time_it(|| finger_htilde(&g));
    println!("exact H    = {h:.6}  ({})", fmt::secs(t_h));
    println!("FINGER-Ĥ  = {hhat:.6}  ({}, CTRR {})", fmt::secs(t_hat),
             fmt::pct(finger::util::timer::ctrr(t_h, t_hat)));
    println!("FINGER-H̃ = {htil:.6}  ({}, CTRR {})", fmt::secs(t_til),
             fmt::pct(finger::util::timer::ctrr(t_h, t_til)));
    assert!(htil <= hhat + 1e-9 && hhat <= h + 1e-6, "H̃ ≤ Ĥ ≤ H violated");

    // --- JS distance between two perturbed snapshots (Algorithm 1) ---
    let mut g2 = g.clone();
    let edges: Vec<_> = g.edges().take(200).collect();
    for (i, j, _) in edges {
        g2.remove_edge(i, j);
    }
    let (d_fast, t_fast) = time_it(|| jsdist_fast(&g, &g2));
    let (d_exact, t_exact) = time_it(|| jsdist_exact(&g, &g2));
    println!("\nJSdist fast  = {d_fast:.6} ({})", fmt::secs(t_fast));
    println!("JSdist exact = {d_exact:.6} ({})", fmt::secs(t_exact));

    // --- incremental JS distance over a delta stream (Algorithm 2) ---
    let mut state = FingerState::new(g.clone());
    let mut total = 0.0;
    let (_, t_inc) = time_it(|| {
        for step in 0..50 {
            let mut d = DeltaGraph::new();
            for _ in 0..20 {
                let i = rng.below(n) as u32;
                let j = (i + 1 + rng.below(n - 1) as u32) % n as u32;
                if i != j {
                    d.add(i, j, rng.uniform(0.2, 1.0));
                }
            }
            total += jsdist_incremental(&mut state, &d.coalesced());
            let _ = step;
        }
    });
    println!("\n50 incremental JSdist windows in {} (Σ = {total:.4})", fmt::secs(t_inc));
    println!("final H̃ after stream: {:.6}", state.htilde());
}
