//! Wikipedia-analog anomaly detection (Table 2 / Table S1 / Fig 3).
//!
//! Generates the four synthetic evolving hyperlink networks, scores all nine
//! methods against the VEO anomaly proxy, and prints PCC/SRCC + timings.
//!
//! ```bash
//! cargo run --release --offline --example wikipedia_anomaly [-- --scale 2.0]
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::cli::Args;
use finger::coordinator::{experiments, report};
use finger::datasets::WikiConfig;

fn main() {
    let args = Args::from_env();
    let scale = args.get_parsed("scale", 1.0f64);
    println!("== Table 1 analog: dataset stats ==  (scale={scale})");
    for name in ["sen", "en", "fr", "ge"] {
        let cfg = WikiConfig::preset(name, scale);
        let run = experiments::run_wiki(name, &cfg);
        println!("\n== Table 2/S1 analog: {name} ==");
        println!("{}", report::wiki_table(&run));
        let best = run
            .rows
            .iter()
            .max_by(|a, b| a.pcc.partial_cmp(&b.pcc).unwrap())
            .unwrap();
        println!("best PCC: {} ({:.4})", best.method, best.pcc);
        if name == "en" {
            println!("\n== Fig 3 analog: dissimilarity series (en) ==");
            println!("{}", report::series_dump(&run));
        }
    }
}
