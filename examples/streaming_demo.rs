//! End-to-end driver: the full streaming pipeline on a realistic workload.
//!
//! Generates a large wiki-like delta stream (~10⁵ edge events over monthly
//! windows), pushes it through the threaded source → batcher → scorer → sink
//! pipeline (incremental FINGER, Algorithm 2, on the hot path), and reports
//! throughput, latency percentiles and the anomalies flagged online.
//! The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --offline --example streaming_demo [-- --months 60 --growth 400]
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::cli::Args;
use finger::datasets::{wiki_stream, WikiConfig};
use finger::stream::{event, Pipeline, PipelineConfig};
use finger::util::fmt;

fn main() {
    let args = Args::from_env();
    let cfg = WikiConfig {
        months: args.get_parsed("months", 60usize),
        initial_nodes: args.get_parsed("initial", 2000usize),
        growth_per_month: args.get_parsed("growth", 400usize),
        churn_frac: 0.02,
        burst_months: 4,
        burst_factor: 8.0,
        seed: args.get_parsed("seed", 0x57AEu64),
        ..Default::default()
    };
    println!(
        "workload: months={} initial={} growth={}/mo churn={:.1}% bursts={}",
        cfg.months,
        cfg.initial_nodes,
        cfg.growth_per_month,
        cfg.churn_frac * 100.0,
        cfg.burst_months
    );
    let stream = wiki_stream(&cfg);
    let events = event::events_from_deltas(&stream.deltas);
    println!(
        "events: {} ({} windows) | ground-truth burst months: {:?}\n",
        events.len(),
        stream.deltas.len(),
        stream.burst_months
    );

    let pcfg = PipelineConfig {
        channel_capacity: args.get_parsed("capacity", 64usize),
        anomaly_sigma: 2.5,
        ..Default::default()
    };
    let res = Pipeline::new(stream.initial, pcfg).run(events);

    println!("== pipeline result ==");
    println!("windows scored : {}", res.records.len());
    println!("events ingested: {}", res.total_events);
    println!("wall time      : {}", fmt::secs(res.wall_secs));
    println!("throughput     : {:.0} events/s", res.throughput);
    println!("window latency : p50={} p99={}", fmt::secs(res.p50_latency), fmt::secs(res.p99_latency));
    let last = res.records.last().expect("no windows");
    println!("final graph    : n={} m={} H̃={:.5}", last.nodes, last.edges, last.htilde);

    // flagged anomalies vs ground-truth burst months (window w = month w+1)
    let flagged: Vec<usize> = res.anomalies.iter().map(|w| w + 1).collect();
    println!("\nanomalies flagged at months: {flagged:?}");
    println!("ground-truth burst months:   {:?}", stream.burst_months);
    let hits = stream.burst_months.iter().filter(|m| flagged.contains(m)).count();
    println!(
        "recall: {}/{} bursts flagged online",
        hits,
        stream.burst_months.len()
    );

    println!("\nper-window scores:");
    for r in &res.records {
        let bar_len = (r.jsdist * 400.0).min(60.0) as usize;
        println!(
            "month {:>3} n={:>6} m={:>7} js={:.5} {}{}",
            r.window + 1,
            r.nodes,
            r.edges,
            r.jsdist,
            "#".repeat(bar_len),
            if r.anomalous { "  << ANOMALY" } else { "" }
        );
    }
}
