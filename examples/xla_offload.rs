//! XLA offload check: run Q / Ĥ / JSdist through the AOT artifacts (L2 JAX
//! graphs + L1 Pallas kernels compiled to HLO, executed via PJRT) and
//! cross-check against the native Rust implementations.
//!
//! Requires `make artifacts` first.
//!
//! ```bash
//! cargo run --release --offline --example xla_offload [-- --artifacts artifacts]
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::cli::Args;
use finger::entropy::{finger_hhat, quadratic_q};
use finger::runtime::{Runtime, XlaEntropy};
use finger::util::{fmt, timer::time_it, Pcg64};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = args.get("artifacts").unwrap_or("artifacts").to_string();
    let rt = Runtime::load(&dir)?;
    println!("PJRT platform: {} | artifacts: {:?}", rt.platform(), rt.manifest().sizes("hhat_dense"));
    let x = XlaEntropy::new(&rt);

    let mut rng = Pcg64::new(11);
    let mut worst_q = 0.0f64;
    let mut worst_h = 0.0f64;
    for &n in &[40usize, 100, 200] {
        let g = finger::generators::erdos_renyi_avg_degree(n, 12.0, &mut rng);
        let q_native = quadratic_q(&g);
        let (q_xla, tq) = time_it(|| x.q(&g).expect("q offload"));
        let h_native = finger_hhat(&g);
        let (h_xla, th) = time_it(|| x.hhat(&g).expect("hhat offload"));
        worst_q = worst_q.max((q_native - q_xla).abs());
        worst_h = worst_h.max((h_native - h_xla).abs());
        println!(
            "n={n:<4} Q: native={q_native:.6} xla={q_xla:.6} ({}) | Ĥ: native={h_native:.6} xla={h_xla:.6} ({})",
            fmt::secs(tq),
            fmt::secs(th)
        );
    }

    // JS distance offload on a perturbed pair
    let a = finger::generators::erdos_renyi_avg_degree(200, 10.0, &mut rng);
    let mut b = a.clone();
    let edges: Vec<_> = a.edges().take(60).collect();
    for (i, j, _) in edges {
        b.remove_edge(i, j);
    }
    let native = finger::distance::jsdist_fast(&a, &b);
    let (xla, t) = time_it(|| x.jsdist(&a, &b).expect("jsdist offload"));
    println!("JSdist: native={native:.6} xla={xla:.6} |Δ|={:.2e} ({})", (native - xla).abs(), fmt::secs(t));

    println!("\nworst |Δ|: Q={worst_q:.2e}  Ĥ={worst_h:.2e}");
    println!("compile cache holds {} executables", rt.cached_count());
    anyhow::ensure!(worst_q < 1e-4 && worst_h < 5e-3, "offload deviates from native");
    println!("offload OK");
    Ok(())
}
