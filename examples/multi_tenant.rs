//! Multi-tenant serving demo: ~1k concurrent sessions scored by the sharded
//! scoring service, with per-session anomaly detection and a checkpoint/
//! restore round-trip at the end.
//!
//! ```bash
//! cargo run --release --offline --example multi_tenant \
//!     [-- --sessions 1000 --shards 8 --windows 12 --events 50]
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::cli::Args;
use finger::service::{workload, ScoringService, ServiceConfig, TenantWorkloadConfig};
use finger::stream::StreamEvent;

fn main() {
    let args = Args::from_env();
    let wl_cfg = TenantWorkloadConfig {
        sessions: args.get_parsed("sessions", 1000usize).max(1),
        windows: args.get_parsed("windows", 12usize).max(1),
        events_per_window: args.get_parsed("events", 50usize).max(1),
        nodes_per_session: args.get_parsed("nodes", 48usize).max(2),
        seed: args.get_parsed("seed", 0xABCDu64),
        ..Default::default()
    };
    let svc_cfg = ServiceConfig {
        shards: args.get_parsed("shards", 8usize).max(1),
        ..Default::default()
    };
    println!(
        "driving {} sessions ({} windows × {} events each) through {} shards...",
        wl_cfg.sessions, wl_cfg.windows, wl_cfg.events_per_window, svc_cfg.shards
    );
    let streams = workload::tenant_streams(&wl_cfg);

    // To make anomaly detection interesting, splice an edit storm into a few
    // tenants' final window: 30× the usual event count.
    let mut streams = streams;
    let storm_sessions: Vec<String> =
        streams.iter().take(3).map(|(id, _, _)| id.clone()).collect();
    for (id, initial, events) in streams.iter_mut() {
        if !storm_sessions.contains(id) {
            continue;
        }
        let n = initial.num_nodes() as u32;
        let tick = events.pop(); // reopen the final window
        for k in 0..(wl_cfg.events_per_window as u32 * 30) {
            events.push(StreamEvent::EdgeDelta {
                i: k % n,
                j: (k * 7 + 1) % n,
                dw: 1.0,
            });
        }
        if let Some(t) = tick {
            events.push(t);
        }
    }

    let report = workload::drive(&svc_cfg, &streams, 8, true).expect("drive workload");
    println!(
        "scored {} events across {} sessions in {:.3}s → {:.2e} events/s aggregate",
        report.total_events,
        report.sessions.len(),
        report.wall_secs,
        report.throughput
    );
    println!(
        "windows scored: {}   anomalies flagged: {}",
        report.total_windows(),
        report.total_anomalies()
    );
    let mut flagged: Vec<&str> = report
        .sessions
        .iter()
        .filter(|s| !s.anomalies.is_empty())
        .map(|s| s.id.as_str())
        .collect();
    flagged.sort();
    println!("sessions with anomalies: {flagged:?}");
    for id in &storm_sessions {
        let s = report.session(id).expect("storm session scored");
        println!(
            "  {id}: H̃={:.4} n={} m={} anomalous windows {:?} (storm was window {})",
            s.htilde,
            s.nodes,
            s.edges,
            s.anomalies,
            wl_cfg.windows - 1
        );
    }

    // checkpoint → restore round-trip for one tenant
    let dir = std::env::temp_dir().join("finger_multi_tenant_demo");
    std::fs::remove_dir_all(&dir).ok(); // stale checkpoints from aborted runs
    let ckpt_cfg =
        ServiceConfig { checkpoint_dir: Some(dir.clone()), shards: 2, ..Default::default() };
    let small: Vec<_> = streams.into_iter().take(4).collect();
    let first_report = workload::drive(&ckpt_cfg, &small, 2, true).expect("drive workload");
    let svc = ScoringService::start(ckpt_cfg);
    let restored = svc.restore_sessions(&dir).expect("restore sessions");
    let resumed = svc.finish();
    println!(
        "checkpointed {} sessions, restored {restored}; H̃ preserved: {}",
        first_report.sessions.len(),
        resumed
            .sessions
            .iter()
            .all(|s| (s.htilde - first_report.session(&s.id).unwrap().htilde).abs() < 1e-12)
    );
    std::fs::remove_dir_all(&dir).ok();
}
