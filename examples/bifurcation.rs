//! Bifurcation detection in dynamic genomic networks (Fig 4 analog).
//!
//! Generates the Hi-C-like 12-sample contact-map sequence (ground-truth
//! bifurcation at measurement 6), computes the TDS of every method and
//! reports which methods detect the correct instant.
//!
//! ```bash
//! cargo run --release --offline --example bifurcation [-- --dim 240]
//! ```

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::cli::Args;
use finger::coordinator::{experiments, report};
use finger::datasets::HicConfig;

fn main() {
    let args = Args::from_env();
    let cfg = HicConfig { dim: args.get_parsed("dim", 240usize), ..Default::default() };
    println!(
        "Hi-C-like sequence: dim={} samples={} ground truth at measurement {}\n",
        cfg.dim, cfg.samples, cfg.bifurcation
    );
    let rows = experiments::run_bifurcation(&cfg);
    println!("{}", report::bifurcation_table(&rows, cfg.bifurcation));
    let correct: Vec<&str> =
        rows.iter().filter(|r| r.correct).map(|r| r.method.as_str()).collect();
    println!("methods uniquely detecting the ground truth: {correct:?}");
}
